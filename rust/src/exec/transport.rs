//! Subprocess worker transport for the sweep fabric — real processes
//! behind the PR-5 coordinator contract.
//!
//! [`crate::exec::fabric`] proved the coordinator/worker protocol
//! (range-keyed shards, heartbeats, bounded retry/backoff, idempotent
//! checksum-verified acceptance, graceful degradation) against a
//! deterministic single-process simulation.  This module runs the same
//! contract over **pipes to spawned `lorax worker` subprocesses**, so
//! `lorax sweep --fabric --transport process` executes shards in
//! genuinely isolated OS processes:
//!
//! * **frames** — every message is one length-prefixed frame:
//!   `[u32 LE payload length][u64 LE FNV-1a-64 of payload][payload]`.
//!   A truncated frame, a bit-flipped payload (checksum mismatch), an
//!   oversized length prefix, or EOF mid-frame each surface as a typed
//!   [`TransportError`] — never a panic (the module is under
//!   `deny(unwrap_used, expect_used)` like `fabric` and `trace_file`);
//! * **messages** — a registry-free binary codec (std only, like the
//!   raw `mmap(2)` shim in [`crate::exec::trace_file`]) carrying the
//!   fabric messages: cells travel as [`crate::exec::ExperimentSpec`]
//!   text forms, results as `lorax run --json` NDJSON records, so
//!   successful cells are **byte-identical** to the in-process sweep;
//! * **failure mapping** — the simulated [`crate::exec::FaultPlan`]
//!   kinds map onto real process faults: `crash` is a SIGKILLed or
//!   aborted worker (detected by pipe EOF or wall-clock heartbeat
//!   silence, respawned with its shard reassigned), `corrupt` is a
//!   checksum-failed frame or payload (a failed attempt that retries),
//!   `drop` is a lost completion (shard deadline, retry), `delay` is a
//!   slow completion (idempotent late acceptance).  Workers opt into
//!   deterministic self-faults via `LORAX_WORKER_FAULTS` (tests), and
//!   the coordinator can SIGKILL a worker right after an assignment via
//!   [`ProcessFabricConfig::kill_after_assign`];
//! * **config shipping** — the coordinator sends its resolved
//!   [`SystemConfig`] as `section.key=value` overrides
//!   ([`SystemConfig::to_overrides`], lossless), so every worker builds
//!   an identical session and the grid stays deterministic.
//!
//! The coordinator reuses the fabric's building blocks unchanged:
//! [`crate::exec::runner::shard_cells`] sharding, the
//! [`crate::exec::FabricHealth`] counters, the ordered
//! [`crate::exec::SweepReport`] (with `O = String`, the opaque NDJSON
//! record), and the same payload fingerprint fold.  See "Transport &
//! serve" in docs/ARCHITECTURE.md.

#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::io::{self, BufReader, Read, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::SystemConfig;

use super::fabric::{payload_checksum, CellState, FabricError, FabricHealth, SweepReport};
use super::runner::{shard_cells, Shard};
use super::trace_file::fnv1a64;

/// Frame header length: u32 payload length + u64 payload checksum.
pub const FRAME_HEADER_LEN: usize = 12;

/// Upper bound on one frame's payload (64 MiB) — a length prefix above
/// this is rejected as [`TransportError::OversizedFrame`] instead of
/// attempting the allocation (a corrupt length prefix must not OOM the
/// coordinator).
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// Typed failure taxonomy of the byte transport — every way a frame,
/// a message, or a worker process can fail, as a value instead of a
/// panic.
#[derive(Debug)]
pub enum TransportError {
    /// The underlying pipe/socket operation failed.
    Io(io::Error),
    /// The stream ended inside a frame (header or payload cut short) —
    /// the classic truncated-frame / killed-peer signature.
    MidFrameEof {
        /// Bytes the reader needed to finish the current section.
        wanted: usize,
        /// Bytes actually available before EOF.
        got: usize,
    },
    /// A frame's length prefix exceeds [`MAX_FRAME_LEN`].
    OversizedFrame {
        /// The declared payload length.
        len: u64,
        /// The configured maximum.
        max: u64,
    },
    /// The payload bytes do not hash to the checksum in the frame
    /// header (bit flip / corruption in transit).
    ChecksumMismatch {
        /// Checksum carried by the frame header.
        stored: u64,
        /// Checksum recomputed over the received payload.
        computed: u64,
    },
    /// A frame's payload is not a well-formed protocol message.
    BadMessage {
        /// What the decoder choked on.
        detail: String,
    },
    /// Spawning a worker subprocess failed.
    Spawn {
        /// Worker slot being spawned.
        worker: usize,
        /// The underlying OS error.
        source: io::Error,
    },
    /// The process fabric was configured with zero workers.
    NoWorkers,
    /// A worker process died (pipe EOF, frame error, or heartbeat
    /// silence), with the last bytes it wrote to stderr attached so
    /// the respawn log says *why* — an abort message, a panic
    /// backtrace — instead of just "worker gone".
    WorkerDied {
        /// Worker slot that died.
        worker: usize,
        /// What the coordinator observed (the triggering frame error,
        /// or the liveness mechanism that fired).
        reason: String,
        /// Bounded tail of the process's captured stderr (empty when
        /// it died silently).
        stderr_tail: String,
    },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Io(e) => write!(f, "transport I/O error: {e}"),
            TransportError::MidFrameEof { wanted, got } => {
                write!(f, "stream ended mid-frame: wanted {wanted} bytes, got {got}")
            }
            TransportError::OversizedFrame { len, max } => {
                write!(f, "frame length {len} exceeds maximum {max}")
            }
            TransportError::ChecksumMismatch { stored, computed } => {
                write!(f, "frame checksum {stored:#018x} != computed {computed:#018x}")
            }
            TransportError::BadMessage { detail } => write!(f, "bad transport message: {detail}"),
            TransportError::Spawn { worker, source } => {
                write!(f, "spawning worker {worker} failed: {source}")
            }
            TransportError::NoWorkers => {
                write!(f, "process fabric configured with zero workers")
            }
            TransportError::WorkerDied { worker, reason, stderr_tail } => {
                write!(f, "worker {worker} died: {reason}")?;
                if stderr_tail.is_empty() {
                    write!(f, " (no stderr output)")
                } else {
                    write!(f, "; stderr tail: {}", stderr_tail.trim_end())
                }
            }
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Io(e) | TransportError::Spawn { source: e, .. } => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for TransportError {
    fn from(e: io::Error) -> TransportError {
        TransportError::Io(e)
    }
}

/// Write one frame (`[len][checksum][payload]`) and flush.
///
/// The frame is composed into one buffer and written with a single
/// `write_all`, so concurrent writers serialized by a mutex (the worker
/// answers heartbeats from its reader thread while the main thread
/// streams results) never interleave partial frames.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<(), TransportError> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(TransportError::OversizedFrame {
            len: payload.len() as u64,
            max: MAX_FRAME_LEN as u64,
        });
    }
    let mut frame = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&fnv1a64(payload).to_le_bytes());
    frame.extend_from_slice(payload);
    w.write_all(&frame)?;
    w.flush()?;
    crate::metric_counter!("transport.frames_sent").inc();
    crate::metric_counter!("transport.bytes_sent").add(frame.len() as u64);
    Ok(())
}

/// Read as many bytes as possible into `buf`; short count means EOF.
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<usize, TransportError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(TransportError::Io(e)),
        }
    }
    Ok(got)
}

/// Read one frame.  `Ok(None)` is a clean EOF at a frame boundary (the
/// peer closed the stream between messages); every other truncation is
/// a typed error: EOF inside the header or payload is
/// [`TransportError::MidFrameEof`], a length prefix over
/// [`MAX_FRAME_LEN`] is [`TransportError::OversizedFrame`], and a
/// payload that does not hash to the header's checksum is
/// [`TransportError::ChecksumMismatch`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>, TransportError> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    let got = read_full(r, &mut header)?;
    if got == 0 {
        return Ok(None);
    }
    if got < FRAME_HEADER_LEN {
        return Err(TransportError::MidFrameEof { wanted: FRAME_HEADER_LEN, got });
    }
    let mut b4 = [0u8; 4];
    b4.copy_from_slice(&header[0..4]);
    let len = u32::from_le_bytes(b4) as usize;
    let mut b8 = [0u8; 8];
    b8.copy_from_slice(&header[4..12]);
    let stored = u64::from_le_bytes(b8);
    if len > MAX_FRAME_LEN {
        return Err(TransportError::OversizedFrame { len: len as u64, max: MAX_FRAME_LEN as u64 });
    }
    let mut payload = vec![0u8; len];
    let got = read_full(r, &mut payload)?;
    if got < len {
        return Err(TransportError::MidFrameEof { wanted: len, got });
    }
    let computed = fnv1a64(&payload);
    if computed != stored {
        crate::metric_counter!("transport.checksum_failures").inc();
        return Err(TransportError::ChecksumMismatch { stored, computed });
    }
    crate::metric_counter!("transport.frames_received").inc();
    crate::metric_counter!("transport.bytes_received")
        .add((FRAME_HEADER_LEN + payload.len()) as u64);
    Ok(Some(payload))
}

// ---------------------------------------------------------------------------
// Message codec
// ---------------------------------------------------------------------------

/// Messages the coordinator sends a worker subprocess.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ToWorker {
    /// Handshake: the coordinator's resolved configuration as
    /// `section.key=value` overrides; the worker builds its session and
    /// answers [`FromWorker::Ready`].
    Init {
        /// [`SystemConfig::to_overrides`] of the coordinator's config.
        overrides: Vec<String>,
    },
    /// Execute one shard of cells (each a spec text form); answered
    /// with [`FromWorker::Done`].
    Assign {
        /// Shard id (the idempotency key).
        shard: u32,
        /// Attempt number (1-based), echoed back for staleness checks.
        attempt: u32,
        /// The shard's cells, in grid order.
        cells: Vec<String>,
    },
    /// Liveness probe; answered with [`FromWorker::Pong`] from the
    /// worker's reader thread even while a shard is computing.
    Ping {
        /// Echoed verbatim in the pong.
        nonce: u64,
    },
    /// Orderly termination request.
    Shutdown,
}

/// Messages a worker subprocess sends the coordinator.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FromWorker {
    /// Handshake reply: the worker built its session from
    /// [`ToWorker::Init`] and is ready for assignments.
    Ready {
        /// The worker's OS process id (diagnostics).
        pid: u32,
    },
    /// Heartbeat reply.
    Pong {
        /// The nonce from the matching [`ToWorker::Ping`].
        nonce: u64,
    },
    /// One completed shard attempt.
    Done {
        /// Shard id from the assignment.
        shard: u32,
        /// Attempt number from the assignment.
        attempt: u32,
        /// Per-cell outcomes in shard order: `Ok` carries the cell's
        /// NDJSON record, `Err` a deterministic execution error.
        cells: Vec<Result<String, String>>,
        /// [`crate::exec::fabric`]-style fingerprint fold over `cells`
        /// (FNV-1a-64 of each record), verified before acceptance.
        checksum: u64,
        /// Telemetry delta since the worker's last shipped snapshot,
        /// in [`crate::telemetry::Snapshot::to_pairs`] wire form.  The
        /// worker advances its shipped mark only after a send goes
        /// out, so a dropped completion's counts ride the next one and
        /// fleet totals stay exact across retries.
        metrics: Vec<(String, u64)>,
    },
}

const TAG_INIT: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_PING: u8 = 3;
const TAG_SHUTDOWN: u8 = 4;
const TAG_READY: u8 = 101;
const TAG_PONG: u8 = 102;
const TAG_DONE: u8 = 103;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked little-endian decoder over one message payload.
struct Dec<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Dec<'a> {
    fn new(bytes: &'a [u8]) -> Dec<'a> {
        Dec { bytes, at: 0 }
    }

    fn bad(&self, what: &str) -> TransportError {
        TransportError::BadMessage {
            detail: format!("{what} at byte {} of a {}-byte message", self.at, self.bytes.len()),
        }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], TransportError> {
        let end = self.at.checked_add(n).filter(|&e| e <= self.bytes.len());
        match end {
            Some(end) => {
                let s = &self.bytes[self.at..end];
                self.at = end;
                Ok(s)
            }
            None => Err(self.bad(what)),
        }
    }

    fn u8(&mut self, what: &str) -> Result<u8, TransportError> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32, TransportError> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4, what)?);
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self, what: &str) -> Result<u64, TransportError> {
        let mut b = [0u8; 8];
        b.copy_from_slice(self.take(8, what)?);
        Ok(u64::from_le_bytes(b))
    }

    fn str(&mut self, what: &str) -> Result<String, TransportError> {
        let len = self.u32(what)? as usize;
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| self.bad(what))
    }

    /// A list length, sanity-bounded so a corrupt count cannot drive a
    /// huge preallocation (each element needs at least one byte).
    fn list_len(&mut self, what: &str) -> Result<usize, TransportError> {
        let n = self.u32(what)? as usize;
        if n > self.bytes.len().saturating_sub(self.at) {
            return Err(self.bad(what));
        }
        Ok(n)
    }

    fn finish(self) -> Result<(), TransportError> {
        if self.at == self.bytes.len() {
            Ok(())
        } else {
            Err(TransportError::BadMessage {
                detail: format!(
                    "{} trailing bytes after a complete message",
                    self.bytes.len() - self.at
                ),
            })
        }
    }
}

impl ToWorker {
    /// Serialize to the binary payload form.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            ToWorker::Init { overrides } => {
                out.push(TAG_INIT);
                put_u32(&mut out, overrides.len() as u32);
                for o in overrides {
                    put_str(&mut out, o);
                }
            }
            ToWorker::Assign { shard, attempt, cells } => {
                out.push(TAG_ASSIGN);
                put_u32(&mut out, *shard);
                put_u32(&mut out, *attempt);
                put_u32(&mut out, cells.len() as u32);
                for c in cells {
                    put_str(&mut out, c);
                }
            }
            ToWorker::Ping { nonce } => {
                out.push(TAG_PING);
                put_u64(&mut out, *nonce);
            }
            ToWorker::Shutdown => out.push(TAG_SHUTDOWN),
        }
        out
    }

    /// Decode a payload produced by [`ToWorker::encode`].
    pub fn decode(bytes: &[u8]) -> Result<ToWorker, TransportError> {
        let mut d = Dec::new(bytes);
        let msg = match d.u8("message tag")? {
            TAG_INIT => {
                let n = d.list_len("override count")?;
                let mut overrides = Vec::with_capacity(n);
                for _ in 0..n {
                    overrides.push(d.str("override string")?);
                }
                ToWorker::Init { overrides }
            }
            TAG_ASSIGN => {
                let shard = d.u32("shard id")?;
                let attempt = d.u32("attempt")?;
                let n = d.list_len("cell count")?;
                let mut cells = Vec::with_capacity(n);
                for _ in 0..n {
                    cells.push(d.str("cell spec")?);
                }
                ToWorker::Assign { shard, attempt, cells }
            }
            TAG_PING => ToWorker::Ping { nonce: d.u64("ping nonce")? },
            TAG_SHUTDOWN => ToWorker::Shutdown,
            t => {
                return Err(TransportError::BadMessage {
                    detail: format!("unknown coordinator message tag {t}"),
                })
            }
        };
        d.finish()?;
        Ok(msg)
    }
}

impl FromWorker {
    /// Serialize to the binary payload form.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            FromWorker::Ready { pid } => {
                out.push(TAG_READY);
                put_u32(&mut out, *pid);
            }
            FromWorker::Pong { nonce } => {
                out.push(TAG_PONG);
                put_u64(&mut out, *nonce);
            }
            FromWorker::Done { shard, attempt, cells, checksum, metrics } => {
                out.push(TAG_DONE);
                put_u32(&mut out, *shard);
                put_u32(&mut out, *attempt);
                put_u64(&mut out, *checksum);
                put_u32(&mut out, cells.len() as u32);
                for c in cells {
                    match c {
                        Ok(s) => {
                            out.push(0);
                            put_str(&mut out, s);
                        }
                        Err(e) => {
                            out.push(1);
                            put_str(&mut out, e);
                        }
                    }
                }
                put_u32(&mut out, metrics.len() as u32);
                for (name, v) in metrics {
                    put_str(&mut out, name);
                    put_u64(&mut out, *v);
                }
            }
        }
        out
    }

    /// Decode a payload produced by [`FromWorker::encode`].
    pub fn decode(bytes: &[u8]) -> Result<FromWorker, TransportError> {
        let mut d = Dec::new(bytes);
        let msg = match d.u8("message tag")? {
            TAG_READY => FromWorker::Ready { pid: d.u32("pid")? },
            TAG_PONG => FromWorker::Pong { nonce: d.u64("pong nonce")? },
            TAG_DONE => {
                let shard = d.u32("shard id")?;
                let attempt = d.u32("attempt")?;
                let checksum = d.u64("checksum")?;
                let n = d.list_len("cell count")?;
                let mut cells = Vec::with_capacity(n);
                for _ in 0..n {
                    let cell = match d.u8("cell outcome tag")? {
                        0 => Ok(d.str("cell record")?),
                        1 => Err(d.str("cell error")?),
                        t => {
                            return Err(TransportError::BadMessage {
                                detail: format!("unknown cell outcome tag {t}"),
                            })
                        }
                    };
                    cells.push(cell);
                }
                let mn = d.list_len("metric count")?;
                let mut metrics = Vec::with_capacity(mn);
                for _ in 0..mn {
                    let name = d.str("metric name")?;
                    let v = d.u64("metric value")?;
                    metrics.push((name, v));
                }
                FromWorker::Done { shard, attempt, cells, checksum, metrics }
            }
            t => {
                return Err(TransportError::BadMessage {
                    detail: format!("unknown worker message tag {t}"),
                })
            }
        };
        d.finish()?;
        Ok(msg)
    }
}

/// The fabric's fingerprint fold over a shard's cell outcomes, with the
/// NDJSON-record fingerprint both the coordinator and workers use.
pub fn cells_checksum(cells: &[Result<String, String>]) -> u64 {
    payload_checksum(cells, &|s: &String| fnv1a64(s.as_bytes()))
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// Tuning for the subprocess coordinator.  The semantics mirror
/// [`crate::exec::FabricConfig`], with the discrete scheduler *steps*
/// replaced by wall-clock [`Duration`]s.
#[derive(Clone, Debug)]
pub struct ProcessFabricConfig {
    /// Worker subprocesses to spawn (>= 1).
    pub workers: usize,
    /// Cells per shard (floor 1).
    pub shard_size: usize,
    /// Total attempts per shard before its cells degrade.
    pub max_attempts: u32,
    /// Heartbeat ping interval.
    pub heartbeat_every: Duration,
    /// Silence past this flips a worker to presumed-crashed (its pipe
    /// EOF usually fires first; the timeout catches hung processes).
    pub heartbeat_timeout: Duration,
    /// Wall-clock deadline per shard attempt.
    pub shard_timeout: Duration,
    /// Base retry backoff (doubles per attempt, capped at
    /// [`ProcessFabricConfig::backoff_cap`]).
    pub backoff_base: Duration,
    /// Retry backoff ceiling.
    pub backoff_cap: Duration,
    /// Total wall-clock budget for the sweep; zero derives a generous
    /// bound from the shard count and timeouts.  On expiry the
    /// remaining cells degrade as [`FabricError::Stalled`].
    pub max_wall: Duration,
    /// Worker respawn budget across the whole sweep; once spent, dead
    /// slots stay dead (and an all-dead pool degrades the remainder).
    pub max_respawns: u32,
    /// Worker executable; `None` spawns `std::env::current_exe()`
    /// (the normal case — `lorax` re-invokes itself as `lorax worker`).
    pub worker_bin: Option<PathBuf>,
    /// Deterministic crash injection: right after assigning shard `s`
    /// to worker slot `w`, SIGKILL that worker (each pair fires once).
    /// This is the real-process analogue of a `crash:<w>@<s>`
    /// [`crate::exec::FaultPlan`] event.
    pub kill_after_assign: Vec<(usize, usize)>,
    /// Worker-side fault events, forwarded as `LORAX_WORKER_FAULTS`
    /// (see [`worker_main`]); empty clears the variable so spawned
    /// workers never inherit stray faults from the environment.
    pub worker_faults: Vec<String>,
}

impl Default for ProcessFabricConfig {
    fn default() -> Self {
        ProcessFabricConfig {
            workers: 4,
            shard_size: 1,
            max_attempts: 4,
            heartbeat_every: Duration::from_millis(100),
            heartbeat_timeout: Duration::from_secs(10),
            shard_timeout: Duration::from_secs(120),
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(1),
            max_wall: Duration::ZERO,
            max_respawns: 8,
            worker_bin: None,
            kill_after_assign: Vec::new(),
            worker_faults: Vec::new(),
        }
    }
}

impl ProcessFabricConfig {
    fn backoff(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(16);
        self.backoff_base.saturating_mul(1u32 << shift).min(self.backoff_cap)
    }

    fn wall_budget(&self, shards: usize) -> Duration {
        if !self.max_wall.is_zero() {
            return self.max_wall;
        }
        let attempts = (shards as u64).saturating_mul(self.max_attempts as u64).max(1);
        self.shard_timeout
            .saturating_mul(attempts.min(u32::MAX as u64) as u32)
            .saturating_add(Duration::from_secs(60))
    }
}

/// Events a worker's pipe-reader thread forwards to the coordinator
/// loop (tagged with the slot's spawn generation so messages from a
/// replaced process are discarded).
enum Event {
    Msg(FromWorker),
    /// The worker's stdout closed (clean EOF or frame error): the
    /// process is gone or its stream is unrecoverable (a length-framed
    /// stream cannot resync after a bad frame), so the coordinator
    /// kills and respawns.
    Dead(Option<TransportError>),
}

/// Post-mortem for one dead worker process: what the coordinator
/// observed and the last bytes the process wrote to stderr.  Collected
/// per run and retrievable via [`ProcessFabric::last_obits`].
#[derive(Clone, Debug)]
pub struct WorkerObit {
    /// Worker slot that died.
    pub worker: usize,
    /// Spawn generation of the dead process (0 = original spawn).
    pub gen: u64,
    /// Why the coordinator declared it dead.
    pub reason: String,
    /// Bounded tail of the process's captured stderr.
    pub stderr_tail: String,
}

/// Bytes of worker stderr retained for the obit tail.
const STDERR_TAIL_CAP: usize = 4096;

/// Tee a worker's piped stderr through to the coordinator's stderr
/// (preserving the old `Stdio::inherit` visibility) while keeping a
/// bounded tail for the obit.  Returns the pump thread's handle; it
/// terminates at pipe EOF, so joining after the child is reaped is
/// bounded.
fn pump_stderr(
    mut stderr: std::process::ChildStderr,
    tail: Arc<Mutex<Vec<u8>>>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut buf = [0u8; 1024];
        loop {
            match stderr.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => {
                    let _ = io::stderr().write_all(&buf[..n]);
                    let mut t = tail.lock().unwrap_or_else(|e| e.into_inner());
                    t.extend_from_slice(&buf[..n]);
                    if t.len() > STDERR_TAIL_CAP {
                        let cut = t.len() - STDERR_TAIL_CAP;
                        t.drain(..cut);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    })
}

/// One worker subprocess slot.
struct Slot {
    child: Child,
    stdin: ChildStdin,
    gen: u64,
    alive: bool,
    up: bool,
    last_seen: Instant,
    busy: Option<usize>,
    stderr_tail: Arc<Mutex<Vec<u8>>>,
    stderr_pump: Option<std::thread::JoinHandle<()>>,
}

/// Coordinator bookkeeping for one outstanding assignment.
struct Flight {
    worker: usize,
    attempt: u32,
    deadline: Instant,
}

/// The subprocess sweep fabric: spawns `lorax worker` children and
/// drives the PR-5 coordinator contract over real pipes.  Construct
/// with [`ProcessFabric::new`], execute with [`ProcessFabric::run`].
pub struct ProcessFabric {
    cfg: ProcessFabricConfig,
    fleet: Mutex<Vec<(String, u64)>>,
    obits: Mutex<Vec<WorkerObit>>,
}

impl ProcessFabric {
    /// Validate the config (>= 1 worker) and build a fabric.
    pub fn new(cfg: ProcessFabricConfig) -> Result<ProcessFabric, TransportError> {
        if cfg.workers == 0 {
            return Err(TransportError::NoWorkers);
        }
        Ok(ProcessFabric { cfg, fleet: Mutex::new(Vec::new()), obits: Mutex::new(Vec::new()) })
    }

    /// The configuration this fabric runs with.
    pub fn config(&self) -> &ProcessFabricConfig {
        &self.cfg
    }

    /// The concatenated telemetry-delta pairs absorbed from workers
    /// during the last [`ProcessFabric::run`] (exactly what was merged
    /// into the coordinator's global registry — one entry per metric
    /// per accepted completion).
    pub fn last_fleet(&self) -> Vec<(String, u64)> {
        self.fleet.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Obits for every worker process declared dead during the last
    /// [`ProcessFabric::run`].
    pub fn last_obits(&self) -> Vec<WorkerObit> {
        self.obits.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Execute `cells` (spec text forms) across worker subprocesses
    /// under `sys`, returning the ordered report.  Successful cells are
    /// the exact `lorax run --json` NDJSON records the workers
    /// produced — byte-identical to the in-process sweep — and cells
    /// whose shards exhaust their budget degrade to
    /// [`CellState::Unfinished`]; the fabric returns a partial report
    /// rather than failing the sweep.  `Err` is reserved for setup
    /// failures (initial spawns).
    pub fn run(
        &self,
        sys: &SystemConfig,
        cells: &[String],
    ) -> Result<SweepReport<String>, TransportError> {
        let shards = shard_cells(cells.len(), self.cfg.shard_size);
        let health = FabricHealth {
            workers: self.cfg.workers,
            shards: shards.len(),
            ..FabricHealth::default()
        };
        if shards.is_empty() {
            return Ok(SweepReport { cells: Vec::new(), health });
        }
        let mut driver = Driver {
            cfg: &self.cfg,
            overrides: sys.to_overrides(),
            cells_in: cells,
            shards,
            slots: Vec::new(),
            tx: None,
            out: vec![None; cells.len()],
            finalized_shard: Vec::new(),
            finalized: 0,
            pending: VecDeque::new(),
            in_flight: BTreeMap::new(),
            last_worker: Vec::new(),
            kills: self.cfg.kill_after_assign.clone(),
            respawns_used: 0,
            health,
            fleet: Vec::new(),
            obits: Vec::new(),
        };
        let report = driver.drive()?;
        *self.fleet.lock().unwrap_or_else(|e| e.into_inner()) = std::mem::take(&mut driver.fleet);
        *self.obits.lock().unwrap_or_else(|e| e.into_inner()) = std::mem::take(&mut driver.obits);
        Ok(report)
    }
}

/// The coordinator event loop state (one [`ProcessFabric::run`]).
struct Driver<'a> {
    cfg: &'a ProcessFabricConfig,
    overrides: Vec<String>,
    cells_in: &'a [String],
    shards: Vec<Shard>,
    slots: Vec<Slot>,
    tx: Option<Sender<(usize, u64, Event)>>,
    out: Vec<Option<CellState<String>>>,
    finalized_shard: Vec<bool>,
    finalized: usize,
    pending: VecDeque<(usize, u32, Instant)>,
    in_flight: BTreeMap<usize, Flight>,
    last_worker: Vec<Option<usize>>,
    kills: Vec<(usize, usize)>,
    respawns_used: u32,
    health: FabricHealth,
    fleet: Vec<(String, u64)>,
    obits: Vec<WorkerObit>,
}

impl Driver<'_> {
    fn drive(&mut self) -> Result<SweepReport<String>, TransportError> {
        let start = Instant::now();
        let wall_deadline = start + self.cfg.wall_budget(self.shards.len());
        let (tx, rx): (Sender<(usize, u64, Event)>, Receiver<(usize, u64, Event)>) =
            mpsc::channel();
        self.tx = Some(tx);
        for w in 0..self.cfg.workers {
            let slot = self.spawn_slot(w, 0)?;
            self.slots.push(slot);
        }
        self.finalized_shard = vec![false; self.shards.len()];
        self.last_worker = vec![None; self.shards.len()];
        self.pending = self.shards.iter().map(|s| (s.id, 1, start)).collect();
        let mut last_ping = start;
        let mut nonce = 0u64;

        while self.finalized < self.shards.len() {
            self.health.steps += 1;
            let now = Instant::now();
            if now >= wall_deadline || self.pool_exhausted() {
                let outstanding = self.shards.len() - self.finalized;
                let err = FabricError::Stalled { step: self.health.steps, outstanding };
                for sid in 0..self.shards.len() {
                    if !self.finalized_shard[sid] {
                        self.degrade(sid, err);
                    }
                }
                break;
            }

            // 1. Drain worker events.
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(ev) => {
                    self.handle_event(ev);
                    while let Ok(ev) = rx.try_recv() {
                        self.handle_event(ev);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {}
            }
            let now = Instant::now();

            // 2. Heartbeats: ping every live worker (its reader thread
            // answers even while a shard computes).
            if now.duration_since(last_ping) >= self.cfg.heartbeat_every {
                last_ping = now;
                nonce += 1;
                for w in 0..self.slots.len() {
                    if self.slots[w].alive && self.slots[w].up {
                        let msg = ToWorker::Ping { nonce };
                        crate::metric_counter!("fabric.heartbeats").inc();
                        if write_frame(&mut self.slots[w].stdin, &msg.encode()).is_err() {
                            self.on_worker_dead(w, self.slots[w].gen, None);
                        }
                    }
                }
            }

            // 3. Failure detection: heartbeat silence past the timeout
            // (covers hung-but-running processes; pipe EOF handles the
            // dead ones first).
            for w in 0..self.slots.len() {
                if self.slots[w].alive
                    && now.duration_since(self.slots[w].last_seen) > self.cfg.heartbeat_timeout
                {
                    self.on_worker_dead(w, self.slots[w].gen, None);
                }
            }

            // 4. Attempt deadlines.
            let expired: Vec<(usize, u32)> = self
                .in_flight
                .iter()
                .filter(|(_, f)| now >= f.deadline)
                .map(|(&sid, f)| (sid, f.attempt))
                .collect();
            for (sid, attempt) in expired {
                if let Some(f) = self.in_flight.remove(&sid) {
                    self.health.timeouts += 1;
                    crate::metric_counter!("fabric.timeouts").inc();
                    if self.slots[f.worker].busy == Some(sid) {
                        self.slots[f.worker].busy = None;
                    }
                    self.retry_or_degrade(sid, attempt, now);
                }
            }

            // 5. Assign ready shards to free workers.
            self.assign_ready(now);
        }

        self.shutdown();
        let cells = std::mem::take(&mut self.out)
            .into_iter()
            .map(|c| {
                c.unwrap_or(CellState::Unfinished(FabricError::Stalled {
                    step: self.health.steps,
                    outstanding: 0,
                }))
            })
            .collect();
        Ok(SweepReport { cells, health: self.health })
    }

    /// True when every slot is dead and the respawn budget is spent —
    /// nothing can make progress, so the remaining shards degrade now
    /// instead of waiting out the wall clock.
    fn pool_exhausted(&self) -> bool {
        self.respawns_used >= self.cfg.max_respawns && self.slots.iter().all(|s| !s.alive)
    }

    fn spawn_slot(&self, worker: usize, respawns: u32) -> Result<Slot, TransportError> {
        let bin = match &self.cfg.worker_bin {
            Some(p) => p.clone(),
            None => std::env::current_exe()
                .map_err(|e| TransportError::Spawn { worker, source: e })?,
        };
        let mut cmd = Command::new(bin);
        cmd.arg("worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .env("LORAX_WORKER_SLOT", worker.to_string())
            .env("LORAX_WORKER_RESPAWN", respawns.to_string());
        if self.cfg.worker_faults.is_empty() {
            cmd.env_remove("LORAX_WORKER_FAULTS");
        } else {
            cmd.env("LORAX_WORKER_FAULTS", self.cfg.worker_faults.join(","));
        }
        let mut child = cmd.spawn().map_err(|e| TransportError::Spawn { worker, source: e })?;
        let mut stdin = match child.stdin.take() {
            Some(s) => s,
            None => {
                return Err(TransportError::Spawn {
                    worker,
                    source: io::Error::new(io::ErrorKind::Other, "child stdin not piped"),
                })
            }
        };
        let stdout = match child.stdout.take() {
            Some(s) => s,
            None => {
                return Err(TransportError::Spawn {
                    worker,
                    source: io::Error::new(io::ErrorKind::Other, "child stdout not piped"),
                })
            }
        };
        let stderr_tail = Arc::new(Mutex::new(Vec::new()));
        let stderr_pump = child
            .stderr
            .take()
            .map(|s| pump_stderr(s, Arc::clone(&stderr_tail)));
        let gen = self.slots.get(worker).map(|s| s.gen + 1).unwrap_or(0);
        let tx = match &self.tx {
            Some(tx) => tx.clone(),
            None => {
                return Err(TransportError::Spawn {
                    worker,
                    source: io::Error::new(io::ErrorKind::Other, "driver not started"),
                })
            }
        };
        std::thread::spawn(move || {
            let mut r = BufReader::new(stdout);
            loop {
                match read_frame(&mut r) {
                    Ok(None) => {
                        let _ = tx.send((worker, gen, Event::Dead(None)));
                        break;
                    }
                    Ok(Some(payload)) => match FromWorker::decode(&payload) {
                        Ok(msg) => {
                            if tx.send((worker, gen, Event::Msg(msg))).is_err() {
                                break;
                            }
                        }
                        Err(e) => {
                            let _ = tx.send((worker, gen, Event::Dead(Some(e))));
                            break;
                        }
                    },
                    Err(e) => {
                        let _ = tx.send((worker, gen, Event::Dead(Some(e))));
                        break;
                    }
                }
            }
        });
        // Handshake: ship the coordinator's config.  A write failure
        // here surfaces as a Dead event from the reader thread, which
        // triggers the normal respawn path.
        let init = ToWorker::Init { overrides: self.overrides.clone() };
        let _ = write_frame(&mut stdin, &init.encode());
        Ok(Slot {
            child,
            stdin,
            gen,
            alive: true,
            up: false,
            last_seen: Instant::now(),
            busy: None,
            stderr_tail,
            stderr_pump,
        })
    }

    fn handle_event(&mut self, (worker, gen, event): (usize, u64, Event)) {
        if worker >= self.slots.len() || self.slots[worker].gen != gen {
            return; // stale: from a process this slot already replaced
        }
        match event {
            Event::Msg(FromWorker::Ready { .. }) => {
                self.slots[worker].up = true;
                self.slots[worker].last_seen = Instant::now();
            }
            Event::Msg(FromWorker::Pong { .. }) => {
                self.slots[worker].last_seen = Instant::now();
            }
            Event::Msg(FromWorker::Done { shard, attempt, cells, checksum, metrics }) => {
                self.on_done(worker, shard as usize, attempt, cells, checksum, metrics);
            }
            Event::Dead(err) => {
                if let Some(e) = &err {
                    if matches!(
                        e,
                        TransportError::ChecksumMismatch { .. }
                            | TransportError::MidFrameEof { .. }
                            | TransportError::OversizedFrame { .. }
                            | TransportError::BadMessage { .. }
                    ) {
                        // A mangled frame is indistinguishable from a
                        // corrupt payload at the fabric level: count it
                        // and fail the attempt via the crash path.
                        self.health.corrupt_payloads += 1;
                    }
                }
                self.on_worker_dead(worker, gen, err);
            }
        }
    }

    #[allow(clippy::needless_range_loop)]
    fn on_done(
        &mut self,
        worker: usize,
        shard: usize,
        attempt: u32,
        cells: Vec<Result<String, String>>,
        checksum: u64,
        metrics: Vec<(String, u64)>,
    ) {
        self.slots[worker].last_seen = Instant::now();
        // Absorb the worker's telemetry delta regardless of what
        // happens to the cells: the worker advances its shipped mark
        // once per send, so every completion — duplicate shards and
        // corrupt payloads included — carries a disjoint slice of
        // worker-side work, and absorbing each exactly once keeps
        // fleet totals exact.
        if !metrics.is_empty() {
            crate::telemetry::global().absorb_pairs(&metrics);
            self.fleet.extend(metrics);
        }
        if self.slots[worker].busy == Some(shard) {
            self.slots[worker].busy = None;
        }
        if shard >= self.shards.len() {
            self.health.corrupt_payloads += 1;
            return;
        }
        if self.finalized_shard[shard] {
            // Idempotent acceptance: completions for finalized shards
            // drop (same rule as the simulated fabric).
            self.health.duplicates_dropped += 1;
            return;
        }
        let sh = self.shards[shard];
        if cells_checksum(&cells) != checksum || cells.len() != sh.len {
            self.health.corrupt_payloads += 1;
            // A corrupt payload fails exactly the attempt it belongs
            // to; stale attempts change nothing.
            let current = self
                .in_flight
                .get(&shard)
                .map(|f| f.worker == worker && f.attempt == attempt)
                .unwrap_or(false);
            if current {
                self.in_flight.remove(&shard);
                self.retry_or_degrade(shard, attempt, Instant::now());
            }
            return;
        }
        // Accept — even a late completion from a timed-out attempt
        // (cell execution is deterministic, so the bytes are the same).
        for (k, i) in sh.range().enumerate() {
            self.out[i] = Some(match &cells[k] {
                Ok(o) => CellState::Done(o.clone()),
                Err(e) => CellState::Failed(e.clone()),
            });
        }
        self.in_flight.remove(&shard);
        self.finalized_shard[shard] = true;
        self.finalized += 1;
    }

    fn on_worker_dead(&mut self, worker: usize, gen: u64, err: Option<TransportError>) {
        if self.slots[worker].gen != gen || !self.slots[worker].alive {
            return;
        }
        self.health.crashed_workers += 1;
        crate::metric_counter!("transport.worker_deaths").inc();
        self.slots[worker].alive = false;
        self.slots[worker].up = false;
        let _ = self.slots[worker].child.kill();
        let _ = self.slots[worker].child.wait();
        // The child is reaped, so its stderr pipe is at EOF: joining
        // the pump is bounded and guarantees the tail holds everything
        // the process managed to write.
        if let Some(h) = self.slots[worker].stderr_pump.take() {
            let _ = h.join();
        }
        let tail = {
            let t = self.slots[worker].stderr_tail.lock().unwrap_or_else(|e| e.into_inner());
            String::from_utf8_lossy(&t).into_owned()
        };
        let reason = match &err {
            Some(e) => e.to_string(),
            None => "pipe closed or heartbeat silence".to_string(),
        };
        let died = TransportError::WorkerDied {
            worker,
            reason: reason.clone(),
            stderr_tail: tail.clone(),
        };
        eprintln!("lorax: {died}; respawning");
        self.obits.push(WorkerObit { worker, gen, reason, stderr_tail: tail });
        // Reassign whatever it was computing as a failed attempt.
        if let Some(sid) = self.slots[worker].busy.take() {
            let stale = self.in_flight.get(&sid).map(|f| f.worker == worker).unwrap_or(false);
            if stale {
                if let Some(f) = self.in_flight.remove(&sid) {
                    self.retry_or_degrade(sid, f.attempt, Instant::now());
                }
            }
        }
        // Respawn while budget remains.
        if self.respawns_used < self.cfg.max_respawns {
            self.respawns_used += 1;
            match self.spawn_slot(worker, self.respawns_used) {
                Ok(slot) => {
                    self.health.respawned_workers += 1;
                    crate::metric_counter!("fabric.respawns").inc();
                    self.slots[worker] = slot;
                }
                Err(_) => {
                    // Slot stays dead; pool_exhausted() degrades the
                    // sweep if nobody is left.
                }
            }
        }
    }

    fn retry_or_degrade(&mut self, shard: usize, attempt: u32, now: Instant) {
        if attempt >= self.cfg.max_attempts {
            self.degrade(
                shard,
                FabricError::AttemptsExhausted { shard, attempts: attempt },
            );
        } else {
            self.health.retries += 1;
            crate::metric_counter!("fabric.retries").inc();
            self.pending.push_back((shard, attempt + 1, now + self.cfg.backoff(attempt)));
        }
    }

    fn degrade(&mut self, shard: usize, err: FabricError) {
        if self.finalized_shard[shard] {
            return;
        }
        for i in self.shards[shard].range() {
            self.out[i] = Some(CellState::Unfinished(err));
        }
        self.health.degraded_cells += self.shards[shard].len as u64;
        self.finalized_shard[shard] = true;
        self.finalized += 1;
        self.in_flight.remove(&shard);
    }

    fn assign_ready(&mut self, now: Instant) {
        for w in 0..self.slots.len() {
            if !(self.slots[w].alive && self.slots[w].up && self.slots[w].busy.is_none()) {
                continue;
            }
            let Some(pos) = self.pending.iter().position(|&(_, _, ready)| ready <= now) else {
                return;
            };
            let Some((sid, attempt, _)) = self.pending.remove(pos) else {
                return;
            };
            if self.finalized_shard[sid] {
                continue;
            }
            let sh = self.shards[sid];
            let msg = ToWorker::Assign {
                shard: sid as u32,
                attempt,
                cells: self.cells_in[sh.range()].to_vec(),
            };
            if write_frame(&mut self.slots[w].stdin, &msg.encode()).is_err() {
                self.pending.push_front((sid, attempt, now));
                self.on_worker_dead(w, self.slots[w].gen, None);
                continue;
            }
            if self.last_worker[sid].map(|prev| prev != w).unwrap_or(false) {
                self.health.reassigned += 1;
            }
            self.last_worker[sid] = Some(w);
            self.slots[w].busy = Some(sid);
            self.in_flight.insert(
                sid,
                Flight { worker: w, attempt, deadline: now + self.cfg.shard_timeout },
            );
            // Deterministic SIGKILL-mid-shard knob: the worker got the
            // assignment and dies before (or while) computing it.
            if let Some(k) = self.kills.iter().position(|&(kw, ks)| kw == w && ks == sid) {
                self.kills.remove(k);
                let _ = self.slots[w].child.kill();
            }
        }
    }

    fn shutdown(&mut self) {
        for slot in &mut self.slots {
            if slot.alive {
                let _ = write_frame(&mut slot.stdin, &ToWorker::Shutdown.encode());
            }
        }
        for slot in &mut self.slots {
            let deadline = Instant::now() + Duration::from_secs(5);
            loop {
                match slot.child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    _ => {
                        let _ = slot.child.kill();
                        let _ = slot.child.wait();
                        break;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------------

/// Worker-side fault kinds for `LORAX_WORKER_FAULTS` — the real-process
/// analogue of [`crate::exec::FaultKind`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WorkerFaultKind {
    /// `abort(2)` before computing the shard (a hard crash; the
    /// coordinator sees pipe EOF).
    Crash,
    /// Compute the shard but never send the completion (the shard
    /// deadline expires and retries).
    Drop,
    /// Send the completion with a corrupted checksum (fails the
    /// attempt's integrity check and retries).
    Corrupt,
    /// Sleep before sending (a slow completion; exercises idempotent
    /// late acceptance).
    Delay,
}

/// One armed worker-side fault event.
#[derive(Clone, Debug)]
struct WorkerFault {
    kind: WorkerFaultKind,
    shard: u32,
    always: bool,
    armed: bool,
}

/// Deterministic worker self-faults parsed from `LORAX_WORKER_FAULTS`
/// (`<kind>:<worker>@<shard>[:always]`, comma-separated — the
/// [`crate::exec::FaultPlan`] grammar plus an `:always` re-arm flag).
/// Events are filtered to this process's `LORAX_WORKER_SLOT`; one-shot
/// events are dropped in respawned processes (`LORAX_WORKER_RESPAWN` >
/// 0) so a crash fault does not crash-loop its slot.  Malformed entries
/// are ignored — this is a test hook, not an input surface.
struct WorkerFaults {
    events: Vec<WorkerFault>,
}

impl WorkerFaults {
    fn from_env() -> WorkerFaults {
        let slot = std::env::var("LORAX_WORKER_SLOT").ok();
        let respawned = std::env::var("LORAX_WORKER_RESPAWN")
            .ok()
            .and_then(|v| v.parse::<u32>().ok())
            .unwrap_or(0)
            > 0;
        let spec = std::env::var("LORAX_WORKER_FAULTS").unwrap_or_default();
        let mut events = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((kind_s, rest)) = part.split_once(':') else { continue };
            let Some((worker_s, loc)) = rest.split_once('@') else { continue };
            if slot.as_deref() != Some(worker_s.trim()) {
                continue;
            }
            let (shard_s, always) = match loc.split_once(':') {
                Some((s, "always")) => (s, true),
                Some(_) => continue,
                None => (loc, false),
            };
            let Ok(shard) = shard_s.trim().parse::<u32>() else { continue };
            let kind = match kind_s.trim() {
                "crash" => WorkerFaultKind::Crash,
                "drop" => WorkerFaultKind::Drop,
                "corrupt" => WorkerFaultKind::Corrupt,
                "delay" => WorkerFaultKind::Delay,
                _ => continue,
            };
            if respawned && !always {
                continue;
            }
            events.push(WorkerFault { kind, shard, always, armed: true });
        }
        WorkerFaults { events }
    }

    fn fires(&mut self, kind: WorkerFaultKind, shard: u32) -> bool {
        for e in &mut self.events {
            if e.armed && e.kind == kind && e.shard == shard {
                if !e.always {
                    e.armed = false;
                }
                return true;
            }
        }
        false
    }
}

/// Serialize one worker→coordinator frame through the shared stdout
/// lock (the reader thread pongs heartbeats concurrently with the main
/// thread's results — the mutex plus single-write framing keeps frames
/// whole).
fn send_msg(out: &Arc<Mutex<io::Stdout>>, msg: &FromWorker) -> Result<(), TransportError> {
    let mut guard = out.lock().unwrap_or_else(|e| e.into_inner());
    write_frame(&mut *guard, &msg.encode())
}

/// The `lorax worker` entry point: speak the framed protocol on
/// stdin/stdout until EOF or [`ToWorker::Shutdown`].
///
/// `build` constructs the cell executor from the coordinator's shipped
/// [`SystemConfig`] (the CLI passes a closure building a
/// [`crate::coordinator::LoraxSession`] and running parsed specs); the
/// executor maps one cell text form to its NDJSON record or a
/// deterministic error string.
///
/// A dedicated reader thread answers [`ToWorker::Ping`] directly, so
/// heartbeats stay live while the main thread computes a long shard —
/// the coordinator's wall-clock liveness check never falsely declares a
/// busy worker crashed.
pub fn worker_main<F, R>(build: F) -> Result<(), TransportError>
where
    F: FnOnce(SystemConfig) -> R,
    R: FnMut(&str) -> Result<String, String>,
{
    let mut faults = WorkerFaults::from_env();
    let out = Arc::new(Mutex::new(io::stdout()));
    let (tx, rx) = mpsc::channel::<ToWorker>();
    let out_reader = Arc::clone(&out);
    std::thread::spawn(move || -> Result<(), TransportError> {
        let mut stdin = io::stdin().lock();
        loop {
            match read_frame(&mut stdin)? {
                None => return Ok(()), // coordinator closed the pipe
                Some(payload) => match ToWorker::decode(&payload)? {
                    ToWorker::Ping { nonce } => {
                        send_msg(&out_reader, &FromWorker::Pong { nonce })?
                    }
                    msg => {
                        if tx.send(msg).is_err() {
                            return Ok(());
                        }
                    }
                },
            }
        }
    });
    let mut build = Some(build);
    let mut exec: Option<R> = None;
    // Telemetry shipped so far: each Done carries the delta since this
    // mark, and the mark only advances after a send goes out — a
    // dropped completion's counts ride the next one.
    let mut last_shipped = crate::telemetry::Snapshot::default();
    for msg in rx {
        match msg {
            ToWorker::Init { overrides } => {
                let mut cfg = SystemConfig::default();
                for o in &overrides {
                    cfg.apply_overrides([o.as_str()]).map_err(|e| {
                        TransportError::BadMessage { detail: format!("bad Init override: {e:#}") }
                    })?;
                }
                if let Some(b) = build.take() {
                    exec = Some(b(cfg));
                }
                send_msg(&out, &FromWorker::Ready { pid: std::process::id() })?;
            }
            ToWorker::Assign { shard, attempt, cells } => {
                if faults.fires(WorkerFaultKind::Crash, shard) {
                    std::process::abort();
                }
                let Some(run) = exec.as_mut() else {
                    return Err(TransportError::BadMessage {
                        detail: "Assign received before Init".to_string(),
                    });
                };
                crate::metric_counter!("worker.shards_run").inc();
                crate::metric_counter!("worker.cells_run").add(cells.len() as u64);
                let outs: Vec<Result<String, String>> =
                    cells.iter().map(|c| run(c)).collect();
                let mut checksum = cells_checksum(&outs);
                if faults.fires(WorkerFaultKind::Corrupt, shard) {
                    checksum ^= 0xDEAD_BEEF;
                }
                if faults.fires(WorkerFaultKind::Delay, shard) {
                    std::thread::sleep(Duration::from_millis(250));
                }
                if faults.fires(WorkerFaultKind::Drop, shard) {
                    continue;
                }
                let snap = crate::telemetry::global().snapshot();
                let metrics = snap.diff(&last_shipped).to_pairs();
                send_msg(
                    &out,
                    &FromWorker::Done { shard, attempt, cells: outs, checksum, metrics },
                )?;
                last_shipped = snap;
            }
            ToWorker::Ping { nonce } => {
                // Normally answered by the reader thread; kept total.
                send_msg(&out, &FromWorker::Pong { nonce })?;
            }
            ToWorker::Shutdown => break,
        }
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn frame_bytes(payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, payload).unwrap();
        buf
    }

    #[test]
    fn frame_roundtrip() {
        for payload in [&b""[..], b"x", b"hello frames", &[0u8; 4096][..]] {
            let buf = frame_bytes(payload);
            assert_eq!(buf.len(), FRAME_HEADER_LEN + payload.len());
            let got = read_frame(&mut &buf[..]).unwrap().unwrap();
            assert_eq!(got, payload);
        }
    }

    #[test]
    fn clean_eof_between_frames_is_none() {
        let empty: &[u8] = &[];
        assert!(read_frame(&mut &empty[..]).unwrap().is_none());
        // Two frames then EOF: both decode, then None.
        let mut buf = frame_bytes(b"a");
        buf.extend_from_slice(&frame_bytes(b"bb"));
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"a");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"bb");
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncated_header_is_mid_frame_eof() {
        let buf = frame_bytes(b"payload");
        for cut in 1..FRAME_HEADER_LEN {
            let got = read_frame(&mut &buf[..cut]);
            match got {
                Err(TransportError::MidFrameEof { wanted, got }) => {
                    assert_eq!(wanted, FRAME_HEADER_LEN);
                    assert_eq!(got, cut);
                }
                other => panic!("cut {cut}: expected MidFrameEof, got {other:?}"),
            }
        }
    }

    #[test]
    fn mid_payload_eof_is_mid_frame_eof() {
        let buf = frame_bytes(b"twelve bytes");
        let cut = FRAME_HEADER_LEN + 5;
        match read_frame(&mut &buf[..cut]) {
            Err(TransportError::MidFrameEof { wanted, got }) => {
                assert_eq!(wanted, 12);
                assert_eq!(got, 5);
            }
            other => panic!("expected MidFrameEof, got {other:?}"),
        }
    }

    #[test]
    fn bit_flipped_payload_is_checksum_mismatch() {
        let mut buf = frame_bytes(b"sensitive bits");
        let n = buf.len();
        buf[n - 3] ^= 0x40;
        match read_frame(&mut &buf[..]) {
            Err(TransportError::ChecksumMismatch { stored, computed }) => {
                assert_ne!(stored, computed);
            }
            other => panic!("expected ChecksumMismatch, got {other:?}"),
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let mut buf = frame_bytes(b"ok");
        buf[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        match read_frame(&mut &buf[..]) {
            Err(TransportError::OversizedFrame { len, max }) => {
                assert_eq!(len, u32::MAX as u64);
                assert_eq!(max, MAX_FRAME_LEN as u64);
            }
            other => panic!("expected OversizedFrame, got {other:?}"),
        }
        // Writer side enforces the same cap.
        let huge = vec![0u8; MAX_FRAME_LEN + 1];
        let mut sink = Vec::new();
        assert!(matches!(
            write_frame(&mut sink, &huge),
            Err(TransportError::OversizedFrame { .. })
        ));
    }

    #[test]
    fn to_worker_codec_roundtrip() {
        let msgs = [
            ToWorker::Init {
                overrides: vec!["run.seed=7".to_string(), "run.scale=0.5".to_string()],
            },
            ToWorker::Assign {
                shard: 3,
                attempt: 2,
                cells: vec!["sobel:LORAX-OOK".to_string(), "fft:baseline".to_string()],
            },
            ToWorker::Ping { nonce: 0xDEAD },
            ToWorker::Shutdown,
        ];
        for m in msgs {
            assert_eq!(ToWorker::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn from_worker_codec_roundtrip() {
        let msgs = [
            FromWorker::Ready { pid: 1234 },
            FromWorker::Pong { nonce: 99 },
            FromWorker::Done {
                shard: 1,
                attempt: 1,
                cells: vec![
                    Ok("{\"name\":\"run\"}\n".to_string()),
                    Err("spec parse failed".to_string()),
                ],
                checksum: 0xFEED,
                metrics: vec![
                    ("c:worker.cells_run".to_string(), 2),
                    ("h:replay.wall_us:n".to_string(), 2),
                ],
            },
            FromWorker::Done {
                shard: 2,
                attempt: 1,
                cells: vec![Ok("{}".to_string())],
                checksum: 0,
                metrics: Vec::new(),
            },
        ];
        for m in msgs {
            assert_eq!(FromWorker::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn garbage_messages_are_typed_errors() {
        assert!(matches!(
            ToWorker::decode(&[]),
            Err(TransportError::BadMessage { .. })
        ));
        assert!(matches!(
            ToWorker::decode(&[0xFF]),
            Err(TransportError::BadMessage { .. })
        ));
        assert!(matches!(
            FromWorker::decode(&[TAG_DONE, 1, 2]),
            Err(TransportError::BadMessage { .. })
        ));
        // Trailing junk after a complete message.
        let mut buf = ToWorker::Shutdown.encode();
        buf.push(0);
        assert!(matches!(
            ToWorker::decode(&buf),
            Err(TransportError::BadMessage { .. })
        ));
        // A corrupt list length cannot drive a huge preallocation.
        let mut buf = vec![TAG_ASSIGN];
        put_u32(&mut buf, 0);
        put_u32(&mut buf, 1);
        put_u32(&mut buf, u32::MAX);
        assert!(matches!(
            ToWorker::decode(&buf),
            Err(TransportError::BadMessage { .. })
        ));
        // Invalid UTF-8 in a string field.
        let mut buf = vec![TAG_INIT];
        put_u32(&mut buf, 1);
        put_u32(&mut buf, 2);
        buf.extend_from_slice(&[0xC3, 0x28]);
        assert!(matches!(
            ToWorker::decode(&buf),
            Err(TransportError::BadMessage { .. })
        ));
    }

    #[test]
    fn zero_workers_is_typed_error() {
        let cfg = ProcessFabricConfig { workers: 0, ..ProcessFabricConfig::default() };
        assert!(matches!(ProcessFabric::new(cfg), Err(TransportError::NoWorkers)));
    }

    #[test]
    fn empty_grid_is_empty_report_without_spawning() {
        // worker_bin points nowhere: an empty grid must not spawn.
        let cfg = ProcessFabricConfig {
            workers: 2,
            worker_bin: Some(PathBuf::from("/nonexistent/lorax")),
            ..ProcessFabricConfig::default()
        };
        let fabric = ProcessFabric::new(cfg).unwrap();
        let report = fabric.run(&SystemConfig::default(), &[]).unwrap();
        assert!(report.cells.is_empty());
        assert_eq!(report.health.shards, 0);
    }

    #[test]
    fn worker_faults_parse_filters_and_arms() {
        std::env::set_var("LORAX_WORKER_SLOT", "1");
        std::env::set_var("LORAX_WORKER_RESPAWN", "0");
        std::env::set_var(
            "LORAX_WORKER_FAULTS",
            "corrupt:1@0,crash:0@2,drop:1@3:always,nonsense,delay:1@",
        );
        let mut f = WorkerFaults::from_env();
        // crash:0@2 is another slot's; malformed entries ignored.
        assert_eq!(f.events.len(), 2);
        assert!(f.fires(WorkerFaultKind::Corrupt, 0));
        assert!(!f.fires(WorkerFaultKind::Corrupt, 0), "one-shot disarms");
        assert!(f.fires(WorkerFaultKind::Drop, 3));
        assert!(f.fires(WorkerFaultKind::Drop, 3), ":always re-arms");
        // Respawned processes drop one-shot events.
        std::env::set_var("LORAX_WORKER_RESPAWN", "1");
        let f2 = WorkerFaults::from_env();
        assert_eq!(f2.events.len(), 1);
        assert_eq!(f2.events[0].kind, WorkerFaultKind::Drop);
        std::env::remove_var("LORAX_WORKER_FAULTS");
        std::env::remove_var("LORAX_WORKER_SLOT");
        std::env::remove_var("LORAX_WORKER_RESPAWN");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let cfg = ProcessFabricConfig::default();
        assert_eq!(cfg.backoff(1), Duration::from_millis(50));
        assert_eq!(cfg.backoff(2), Duration::from_millis(100));
        assert_eq!(cfg.backoff(40), cfg.backoff_cap);
    }
}
