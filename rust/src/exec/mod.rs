//! The parallel sweep engine.
//!
//! Every figure and table of the paper is a sweep — (app × policy ×
//! tuning × traffic) combinations pushed through the workload engines
//! and the cycle-level simulator.  This subsystem makes those sweeps a
//! declarative grid executed in parallel:
//!
//! * [`grid`] — scenario lists: [`grid::AppScenario`] /
//!   [`grid::SynthScenario`] and the [`grid::SweepGrid`] builder;
//! * [`runner`] — [`SweepRunner`], an order-preserving scoped-thread
//!   executor (results are independent of thread count), plus the
//!   [`runner::DecisionTableCache`] that memoizes GWI decision tables
//!   keyed by (policy kind, tuning, modulation) so each is computed once
//!   per sweep rather than once per simulator run;
//! * [`trace_buf`] — [`TraceBuffer`], the structure-of-arrays replay
//!   format with routing resolved at record time, which lets
//!   `Simulator::replay` run allocation-free.
//!
//! `lorax sweep` and all the `benches/` reproduction targets run on
//! this engine; `SweepRunner::with_threads(1)` is the serial reference
//! executor the perf benches compare against.

pub mod grid;
pub mod runner;
pub mod trace_buf;

pub use grid::{synth_stress_grid, AppScenario, SweepGrid, SynthScenario};
pub use runner::{DecisionTableCache, SweepRunner};
pub use trace_buf::{TraceBuffer, FLAG_APPROX, FLAG_PHOTONIC};
