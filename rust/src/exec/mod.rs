//! The parallel experiment-execution subsystem.
//!
//! Every figure and table of the paper is a sweep — (app × policy ×
//! tuning × traffic) combinations pushed through the workload engines
//! and the cycle-level simulator.  This subsystem makes those sweeps a
//! declarative grid of typed specs executed in parallel:
//!
//! * [`spec`] — [`spec::ExperimentSpec`], the typed, validated
//!   description of one experiment (app, policy, tuning, traffic,
//!   topology, modulation), round-trippable through its text form and
//!   executed by [`crate::coordinator::LoraxSession`];
//! * [`grid`] — scenario lists: [`grid::AppScenario`] /
//!   [`grid::SynthScenario`] and the [`grid::SweepGrid`] builder;
//! * [`runner`] — [`SweepRunner`], an order-preserving scoped-thread
//!   executor (results are independent of thread count), plus the
//!   [`runner::DecisionTableCache`] that memoizes GWI decision tables
//!   per (modulation, policy kind, tuning) and the [`runner::KernelCache`]
//!   that memoizes their batched-corruption [`crate::coordinator::KernelTable`]s
//!   under the same key;
//! * [`workload`] — [`workload::WorkloadCache`], memoizing synthesized
//!   datasets and their golden outputs per (app, seed, scale) so sweeps
//!   pay dataset synthesis once per app instead of once per scenario;
//! * [`trace_buf`] — [`TraceBuffer`], the structure-of-arrays replay
//!   format with routing resolved at record time, which lets
//!   `Simulator::replay` run allocation-free, and [`TraceView`], the
//!   borrowed form the replay loop actually consumes;
//! * [`trace_file`] — [`TraceFile`], the versioned mmap-able `.ltrace`
//!   on-disk form of the same columns: `lorax trace record/replay`,
//!   larger-than-RAM traces, and the [`workload::TraceCache`] spill all
//!   ride it (zero-copy replay straight off the page cache), with every
//!   open/validate failure a typed [`trace_file::TraceFileError`];
//! * [`fabric`] — [`fabric::SweepFabric`], the fault-tolerant
//!   coordinator/worker sweep fabric: range-keyed shards through
//!   per-worker mailboxes with heartbeats, bounded retry/backoff,
//!   idempotent result acceptance and graceful degradation to a partial
//!   [`fabric::SweepReport`], plus the [`fabric::FaultPlan`] crash
//!   injection layer that keeps every schedule deterministic;
//! * [`transport`] — the same coordinator contract over real OS
//!   processes: [`transport::ProcessFabric`] spawns `lorax worker`
//!   subprocesses and drives them through length-prefixed,
//!   FNV-checksummed frames on pipes (`lorax sweep --fabric --transport
//!   process`), with every frame/process failure a typed
//!   [`transport::TransportError`] and crashed workers respawned with
//!   their shards reassigned.
//!
//! `lorax run`/`lorax sweep` and all the `benches/` reproduction targets
//! run on this engine; `SweepRunner::with_threads(1)` is the serial
//! reference executor the perf benches compare against.

pub mod fabric;
pub mod grid;
pub mod runner;
pub mod spec;
pub mod trace_buf;
pub mod trace_file;
pub mod transport;
pub mod workload;

pub use fabric::{
    CellState, FabricConfig, FabricError, FabricHealth, FaultEvent, FaultKind, FaultPlan,
    SweepFabric, SweepReport,
};
pub use grid::{synth_stress_grid, AppScenario, SweepGrid, SynthScenario};
pub use runner::{
    shard_cells, trace_replay_shard_size, DecisionTableCache, KernelCache, Shard, SweepRunner,
};
pub use spec::{ExperimentSpec, TopologySpec, TrafficSpec};
pub use trace_buf::{TraceBuffer, TraceView, FLAG_APPROX, FLAG_PHOTONIC};
pub use trace_file::{TraceFile, TraceFileError, TraceFileWriter};
pub use transport::{worker_main, ProcessFabric, ProcessFabricConfig, TransportError, WorkerObit};
pub use workload::{CachedWorkload, TraceCache, WorkloadCache};
