//! The transmission frameworks the paper compares (§5.3), plus the
//! per-application tuning knobs (Table 3).
//!
//! * `Baseline`   — plain Clos PNoC, every wavelength at full power.
//! * `Truncation` — statically truncate a fixed per-app number of LSBs
//!                  (laser off for those wavelengths), loss-oblivious.
//! * `Prior16`    — the framework of [16]: 16 LSBs always transmitted at
//!                  20% laser power, loss-oblivious (LSBs that cannot be
//!                  recovered are still paid for).
//! * `Lorax(m)`   — this paper, over any supported signaling order `m`:
//!                  app-specific (bits, power) from Table 3,
//!                  per-destination choice between reduced power and
//!                  truncation from the GWI loss table.  `LORAX-OOK` and
//!                  `LORAX-PAM4` are the paper's two instances; the
//!                  family is open in the signaling order (`LORAX-PAM8`,
//!                  `LORAX-PAM16`), with the LSB power floor and
//!                  signaling loss coming from the scheme
//!                  ([`crate::phys::SignalingScheme`]).

use crate::phys::params::{Modulation, PhotonicParams};
use crate::phys::signaling::SignalingScheme;

/// Which framework a simulation runs under.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// Plain Clos PNoC, every wavelength at full power.
    Baseline,
    /// Static per-app LSB truncation (laser off), loss-oblivious.
    Truncation,
    /// The framework of [16]: 16 LSBs at 20% power, loss-oblivious.
    Prior16,
    /// LORAX over the given signaling order (its *native* modulation;
    /// an [`crate::exec::ExperimentSpec`] `%mod` override can still run
    /// it on a different fabric).
    Lorax(Modulation),
}

impl PolicyKind {
    /// LORAX on OOK (the paper's headline framework).
    pub const LORAX_OOK: PolicyKind = PolicyKind::Lorax(Modulation::OOK);
    /// LORAX on PAM4 (the paper's second calibrated instance).
    pub const LORAX_PAM4: PolicyKind = PolicyKind::Lorax(Modulation::PAM4);
    /// LORAX on PAM8 (extrapolated device model).
    pub const LORAX_PAM8: PolicyKind = PolicyKind::Lorax(Modulation::PAM8);
    /// LORAX on PAM16 (extrapolated device model).
    pub const LORAX_PAM16: PolicyKind = PolicyKind::Lorax(Modulation::PAM16);

    /// The five frameworks of the paper's §5.3 comparison (Fig. 8).
    pub const ALL: [PolicyKind; 5] = [
        PolicyKind::Baseline,
        PolicyKind::Truncation,
        PolicyKind::Prior16,
        PolicyKind::LORAX_OOK,
        PolicyKind::LORAX_PAM4,
    ];

    /// Every framework the spec/CLI surfaces accept: the paper's five
    /// plus the higher LORAX signaling orders.
    pub const PARSEABLE: [PolicyKind; 7] = [
        PolicyKind::Baseline,
        PolicyKind::Truncation,
        PolicyKind::Prior16,
        PolicyKind::LORAX_OOK,
        PolicyKind::LORAX_PAM4,
        PolicyKind::LORAX_PAM8,
        PolicyKind::LORAX_PAM16,
    ];

    /// Canonical framework name (the spec/CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Baseline => "baseline",
            PolicyKind::Truncation => "truncation",
            PolicyKind::Prior16 => "prior[16]",
            PolicyKind::Lorax(m) => m.lorax_name(),
        }
    }

    /// The signaling order this framework natively runs on.
    pub fn modulation(self) -> Modulation {
        match self {
            PolicyKind::Lorax(m) => m,
            _ => Modulation::OOK,
        }
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for PolicyKind {
    type Err = anyhow::Error;

    /// Parse a framework by its canonical [`PolicyKind::name`]
    /// (case-insensitive); the error lists the valid names.
    fn from_str(s: &str) -> Result<PolicyKind, anyhow::Error> {
        PolicyKind::PARSEABLE
            .iter()
            .copied()
            .find(|k| k.name().eq_ignore_ascii_case(s))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown policy {s:?} (one of: {})",
                    PolicyKind::PARSEABLE.map(|k| k.name()).join(", ")
                )
            })
    }
}

/// How one transfer's LSB wavelengths are driven.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TransferMode {
    /// All wavelengths at full power (MSB-only or non-approximable data).
    FullPower,
    /// LSB wavelengths driven at `level` (fraction of full launch power).
    Reduced { level: f64 },
    /// LSB wavelengths off.
    Truncated,
}

/// Per-application approximation parameters (the knobs of Table 3).
/// `Eq + Hash` so (policy, tuning, modulation) can key the sweep
/// engine's memoized decision tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AppTuning {
    /// LSBs approximated under LORAX (of the low word of each double).
    pub approx_bits: u32,
    /// Laser power *reduction* for those LSBs, percent (100 = off).
    pub power_reduction_pct: u32,
    /// LSBs statically truncated under the `Truncation` framework.
    pub trunc_bits: u32,
}

impl AppTuning {
    /// Laser level (fraction of full) for LSB wavelengths under LORAX.
    pub fn level(&self) -> f64 {
        1.0 - self.power_reduction_pct as f64 / 100.0
    }
}

/// The paper's literal Table 3 (for the comparison column in reports).
///
/// Note these are *not* used as runtime defaults: under this
/// implementation's physically-consistent SP channel model, truncating
/// all 32 bits of a word zeroes the value outright, which several of the
/// paper's entries do not survive (DESIGN.md §Deviations).
pub fn paper_table3(app: &str) -> AppTuning {
    match app {
        "blackscholes" => AppTuning { approx_bits: 32, power_reduction_pct: 90, trunc_bits: 12 },
        "canneal" => AppTuning { approx_bits: 32, power_reduction_pct: 100, trunc_bits: 32 },
        "fft" => AppTuning { approx_bits: 32, power_reduction_pct: 50, trunc_bits: 8 },
        "jpeg" => AppTuning { approx_bits: 24, power_reduction_pct: 80, trunc_bits: 20 },
        "sobel" => AppTuning { approx_bits: 32, power_reduction_pct: 100, trunc_bits: 32 },
        "streamcluster" => AppTuning { approx_bits: 28, power_reduction_pct: 80, trunc_bits: 12 },
        _ => AppTuning { approx_bits: 16, power_reduction_pct: 50, trunc_bits: 8 },
    }
}

/// Default per-app tuning for this implementation, measured with
/// `lorax tune --scale 0.1` (the Table-3 search over the full Fig.-6
/// grid) under the 10% output-error ceiling.  Regenerate after changing
/// the channel model (EXPERIMENTS.md records the run).
pub fn table3_defaults(app: &str) -> AppTuning {
    match app {
        "blackscholes" => AppTuning { approx_bits: 20, power_reduction_pct: 80, trunc_bits: 16 },
        // canneal's approximable floats only steer its annealing search,
        // so it tolerates deep approximation.
        "canneal" => AppTuning { approx_bits: 32, power_reduction_pct: 80, trunc_bits: 20 },
        "fft" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "jpeg" => AppTuning { approx_bits: 32, power_reduction_pct: 70, trunc_bits: 20 },
        "sobel" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "streamcluster" => AppTuning { approx_bits: 12, power_reduction_pct: 100, trunc_bits: 12 },
        _ => AppTuning { approx_bits: 12, power_reduction_pct: 50, trunc_bits: 8 },
    }
}

/// PAM4-specific per-app tuning, measured with a `LORAX-PAM4` sweep
/// (`scale 0.1`, full grid): the 1.5x LSB power floor and the PAM4
/// detectability threshold push the energy-optimal choice to deep
/// mantissa-only truncation for every app.
pub fn table3_defaults_pam4(app: &str) -> AppTuning {
    match app {
        "blackscholes" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "canneal" => AppTuning { approx_bits: 20, power_reduction_pct: 100, trunc_bits: 20 },
        "fft" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "jpeg" => AppTuning { approx_bits: 20, power_reduction_pct: 100, trunc_bits: 20 },
        "sobel" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "streamcluster" => AppTuning { approx_bits: 12, power_reduction_pct: 100, trunc_bits: 12 },
        _ => AppTuning { approx_bits: 12, power_reduction_pct: 100, trunc_bits: 12 },
    }
}

/// Tuning for a (kind, app) pair: multilevel LORAX policies use the
/// PAM4-swept table (its deep mantissa-only truncations transfer to the
/// higher orders, whose power floor and detectability threshold are at
/// least as strict — see [`crate::phys::signaling`]).
pub fn default_tuning(kind: PolicyKind, app: &str) -> AppTuning {
    match kind {
        PolicyKind::Lorax(m) if m != Modulation::OOK => table3_defaults_pam4(app),
        _ => table3_defaults(app),
    }
}

/// A fully-resolved policy for one application run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Policy {
    /// The framework family.
    pub kind: PolicyKind,
    /// The per-application knobs the framework runs with.
    pub tuning: AppTuning,
}

impl Policy {
    /// `kind` with the measured Table-3 default tuning for `app`.
    pub fn new(kind: PolicyKind, app: &str) -> Policy {
        Policy { kind, tuning: default_tuning(kind, app) }
    }

    /// `kind` with an explicit tuning.
    pub fn with_tuning(kind: PolicyKind, tuning: AppTuning) -> Policy {
        Policy { kind, tuning }
    }

    /// Number of approximable LSBs for this policy (0 = none).
    pub fn approx_bits(&self) -> u32 {
        match self.kind {
            PolicyKind::Baseline => 0,
            PolicyKind::Truncation => self.tuning.trunc_bits,
            PolicyKind::Prior16 => 16,
            PolicyKind::Lorax(_) => self.tuning.approx_bits,
        }
    }

    /// Commanded LSB laser level *before* the loss-aware decision
    /// (the decision may turn it into 0 for far destinations).
    ///
    /// `fabric` is the signaling order of the waveguide the transfer
    /// rides on: §4.2's LSB power floor is a property of the multilevel
    /// eye, so it applies per fabric (1.0 for OOK, compounding 1.5x per
    /// extra bit-per-symbol above it).
    pub fn commanded_level(&self, p: &PhotonicParams, fabric: Modulation) -> f64 {
        match self.kind {
            PolicyKind::Baseline => 1.0,
            PolicyKind::Truncation => 0.0,
            PolicyKind::Prior16 => 0.2,
            PolicyKind::Lorax(_) => {
                (self.tuning.level() * fabric.scheme().power_floor(p)).min(1.0)
            }
        }
    }

    /// Does this policy consult the loss table per destination?
    pub fn loss_aware(&self) -> bool {
        matches!(self.kind, PolicyKind::Lorax(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table3_matches_paper() {
        let bs = paper_table3("blackscholes");
        assert_eq!((bs.approx_bits, bs.power_reduction_pct, bs.trunc_bits), (32, 90, 12));
        let fft = paper_table3("fft");
        assert_eq!((fft.approx_bits, fft.power_reduction_pct, fft.trunc_bits), (32, 50, 8));
        let jpeg = paper_table3("jpeg");
        assert_eq!((jpeg.approx_bits, jpeg.power_reduction_pct, jpeg.trunc_bits), (24, 80, 20));
        assert_eq!(paper_table3("canneal").power_reduction_pct, 100);
        assert_eq!(paper_table3("sobel").trunc_bits, 32);
        assert_eq!(paper_table3("streamcluster").approx_bits, 28);
    }

    #[test]
    fn our_defaults_exist_for_all_evaluated_apps() {
        for app in crate::apps::EVALUATED_APPS {
            let t = table3_defaults(app);
            assert!(t.approx_bits >= t.trunc_bits || app == "canneal", "{app}");
            assert!(t.approx_bits <= 32 && t.power_reduction_pct <= 100, "{app}");
        }
    }

    #[test]
    fn level_from_reduction() {
        let t = AppTuning { approx_bits: 32, power_reduction_pct: 80, trunc_bits: 0 };
        assert!((t.level() - 0.2).abs() < 1e-12);
        let t = AppTuning { approx_bits: 32, power_reduction_pct: 100, trunc_bits: 0 };
        assert_eq!(t.level(), 0.0);
    }

    #[test]
    fn policy_bits_per_kind() {
        let p = Policy::new(PolicyKind::Baseline, "fft");
        assert_eq!(p.approx_bits(), 0);
        let p = Policy::new(PolicyKind::Truncation, "fft");
        assert_eq!(p.approx_bits(), table3_defaults("fft").trunc_bits);
        let p = Policy::new(PolicyKind::Prior16, "fft");
        assert_eq!(p.approx_bits(), 16);
        let p = Policy::new(PolicyKind::LORAX_OOK, "fft");
        assert_eq!(p.approx_bits(), table3_defaults("fft").approx_bits);
    }

    #[test]
    fn commanded_levels() {
        let phot = PhotonicParams::default(); // pam4_power_factor = 1.5
        let p = Policy::new(PolicyKind::Prior16, "fft");
        assert!((p.commanded_level(&phot, Modulation::OOK) - 0.2).abs() < 1e-12);
        let t = AppTuning { approx_bits: 16, power_reduction_pct: 50, trunc_bits: 8 };
        let p = Policy::with_tuning(PolicyKind::LORAX_OOK, t);
        assert!((p.commanded_level(&phot, Modulation::OOK) - 0.5).abs() < 1e-12);
        let p = Policy::with_tuning(PolicyKind::LORAX_PAM4, t); // 1.5x floor
        assert!((p.commanded_level(&phot, Modulation::PAM4) - 0.75).abs() < 1e-12);
        // The floor compounds per extra bit-per-symbol: PAM8 = 2.25x,
        // so 0.5 * 2.25 saturates at full power.
        let p = Policy::with_tuning(PolicyKind::LORAX_PAM8, t);
        assert_eq!(p.commanded_level(&phot, Modulation::PAM8), 1.0);
        let t30 = AppTuning { approx_bits: 16, power_reduction_pct: 70, trunc_bits: 8 };
        let p = Policy::with_tuning(PolicyKind::LORAX_PAM8, t30);
        assert!((p.commanded_level(&phot, Modulation::PAM8) - 0.675).abs() < 1e-12);
        // Multilevel levels saturate at full power.
        let p = Policy::with_tuning(
            PolicyKind::LORAX_PAM4,
            AppTuning { approx_bits: 32, power_reduction_pct: 10, trunc_bits: 0 },
        );
        assert_eq!(p.commanded_level(&phot, Modulation::PAM4), 1.0);
    }

    #[test]
    fn policy_kind_name_roundtrip() {
        for k in PolicyKind::PARSEABLE {
            assert_eq!(k.name().parse::<PolicyKind>().unwrap(), k);
            assert_eq!(k.to_string(), k.name());
        }
        assert_eq!("lorax-ook".parse::<PolicyKind>().unwrap(), PolicyKind::LORAX_OOK);
        assert_eq!("lorax-pam8".parse::<PolicyKind>().unwrap(), PolicyKind::LORAX_PAM8);
        let err = "nope".parse::<PolicyKind>().unwrap_err().to_string();
        assert!(err.contains("baseline"), "{err}");
        assert!(err.contains("LORAX-PAM8"), "{err}");
    }

    #[test]
    fn native_modulation_per_kind() {
        assert_eq!(PolicyKind::LORAX_PAM4.modulation(), Modulation::PAM4);
        assert_eq!(PolicyKind::LORAX_PAM8.modulation(), Modulation::PAM8);
        assert_eq!(PolicyKind::LORAX_PAM16.modulation(), Modulation::PAM16);
        let ook_native = [
            PolicyKind::Baseline,
            PolicyKind::Truncation,
            PolicyKind::Prior16,
            PolicyKind::LORAX_OOK,
        ];
        for k in ook_native {
            assert_eq!(k.modulation(), Modulation::OOK);
        }
    }

    #[test]
    fn multilevel_lorax_uses_pam4_swept_defaults() {
        for kind in [PolicyKind::LORAX_PAM4, PolicyKind::LORAX_PAM8, PolicyKind::LORAX_PAM16] {
            assert_eq!(default_tuning(kind, "fft"), table3_defaults_pam4("fft"), "{kind}");
        }
        assert_eq!(default_tuning(PolicyKind::LORAX_OOK, "fft"), table3_defaults("fft"));
    }
}
