//! The five transmission frameworks the paper compares (§5.3), plus the
//! per-application tuning knobs (Table 3).
//!
//! * `Baseline`   — plain Clos PNoC, every wavelength at full power.
//! * `Truncation` — statically truncate a fixed per-app number of LSBs
//!                  (laser off for those wavelengths), loss-oblivious.
//! * `Prior16`    — the framework of [16]: 16 LSBs always transmitted at
//!                  20% laser power, loss-oblivious (LSBs that cannot be
//!                  recovered are still paid for).
//! * `LoraxOok`   — this paper: app-specific (bits, power) from Table 3,
//!                  per-destination choice between reduced power and
//!                  truncation from the GWI loss table.
//! * `LoraxPam4`  — LORAX over PAM4 signaling: 32 wavelengths, 1.5x LSB
//!                  power floor, 5.8 dB signaling loss.

use crate::phys::params::Modulation;

/// Which framework a simulation runs under.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    Baseline,
    Truncation,
    Prior16,
    LoraxOok,
    LoraxPam4,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 5] = [
        PolicyKind::Baseline,
        PolicyKind::Truncation,
        PolicyKind::Prior16,
        PolicyKind::LoraxOok,
        PolicyKind::LoraxPam4,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Baseline => "baseline",
            PolicyKind::Truncation => "truncation",
            PolicyKind::Prior16 => "prior[16]",
            PolicyKind::LoraxOok => "LORAX-OOK",
            PolicyKind::LoraxPam4 => "LORAX-PAM4",
        }
    }

    pub fn modulation(self) -> Modulation {
        match self {
            PolicyKind::LoraxPam4 => Modulation::Pam4,
            _ => Modulation::Ook,
        }
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for PolicyKind {
    type Err = anyhow::Error;

    /// Parse a framework by its canonical [`PolicyKind::name`]
    /// (case-insensitive); the error lists the valid names.
    fn from_str(s: &str) -> Result<PolicyKind, anyhow::Error> {
        PolicyKind::ALL
            .iter()
            .copied()
            .find(|k| k.name().eq_ignore_ascii_case(s))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown policy {s:?} (one of: {})",
                    PolicyKind::ALL.map(|k| k.name()).join(", ")
                )
            })
    }
}

/// How one transfer's LSB wavelengths are driven.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TransferMode {
    /// All wavelengths at full power (MSB-only or non-approximable data).
    FullPower,
    /// LSB wavelengths driven at `level` (fraction of full launch power).
    Reduced { level: f64 },
    /// LSB wavelengths off.
    Truncated,
}

/// Per-application approximation parameters (the knobs of Table 3).
/// `Eq + Hash` so (policy, tuning, modulation) can key the sweep
/// engine's memoized decision tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AppTuning {
    /// LSBs approximated under LORAX (of the low word of each double).
    pub approx_bits: u32,
    /// Laser power *reduction* for those LSBs, percent (100 = off).
    pub power_reduction_pct: u32,
    /// LSBs statically truncated under the `Truncation` framework.
    pub trunc_bits: u32,
}

impl AppTuning {
    /// Laser level (fraction of full) for LSB wavelengths under LORAX.
    pub fn level(&self) -> f64 {
        1.0 - self.power_reduction_pct as f64 / 100.0
    }
}

/// The paper's literal Table 3 (for the comparison column in reports).
///
/// Note these are *not* used as runtime defaults: under this
/// implementation's physically-consistent SP channel model, truncating
/// all 32 bits of a word zeroes the value outright, which several of the
/// paper's entries do not survive (DESIGN.md §Deviations).
pub fn paper_table3(app: &str) -> AppTuning {
    match app {
        "blackscholes" => AppTuning { approx_bits: 32, power_reduction_pct: 90, trunc_bits: 12 },
        "canneal" => AppTuning { approx_bits: 32, power_reduction_pct: 100, trunc_bits: 32 },
        "fft" => AppTuning { approx_bits: 32, power_reduction_pct: 50, trunc_bits: 8 },
        "jpeg" => AppTuning { approx_bits: 24, power_reduction_pct: 80, trunc_bits: 20 },
        "sobel" => AppTuning { approx_bits: 32, power_reduction_pct: 100, trunc_bits: 32 },
        "streamcluster" => AppTuning { approx_bits: 28, power_reduction_pct: 80, trunc_bits: 12 },
        _ => AppTuning { approx_bits: 16, power_reduction_pct: 50, trunc_bits: 8 },
    }
}

/// Default per-app tuning for this implementation, measured with
/// `lorax tune --scale 0.1` (the Table-3 search over the full Fig.-6
/// grid) under the 10% output-error ceiling.  Regenerate after changing
/// the channel model (EXPERIMENTS.md records the run).
pub fn table3_defaults(app: &str) -> AppTuning {
    match app {
        "blackscholes" => AppTuning { approx_bits: 20, power_reduction_pct: 80, trunc_bits: 16 },
        // canneal's approximable floats only steer its annealing search,
        // so it tolerates deep approximation.
        "canneal" => AppTuning { approx_bits: 32, power_reduction_pct: 80, trunc_bits: 20 },
        "fft" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "jpeg" => AppTuning { approx_bits: 32, power_reduction_pct: 70, trunc_bits: 20 },
        "sobel" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "streamcluster" => AppTuning { approx_bits: 12, power_reduction_pct: 100, trunc_bits: 12 },
        _ => AppTuning { approx_bits: 12, power_reduction_pct: 50, trunc_bits: 8 },
    }
}

/// PAM4-specific per-app tuning, measured with a `LoraxPam4` sweep
/// (`scale 0.1`, full grid): the 1.5x LSB power floor and the PAM4
/// detectability threshold push the energy-optimal choice to deep
/// mantissa-only truncation for every app.
pub fn table3_defaults_pam4(app: &str) -> AppTuning {
    match app {
        "blackscholes" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "canneal" => AppTuning { approx_bits: 20, power_reduction_pct: 100, trunc_bits: 20 },
        "fft" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "jpeg" => AppTuning { approx_bits: 20, power_reduction_pct: 100, trunc_bits: 20 },
        "sobel" => AppTuning { approx_bits: 16, power_reduction_pct: 100, trunc_bits: 16 },
        "streamcluster" => AppTuning { approx_bits: 12, power_reduction_pct: 100, trunc_bits: 12 },
        _ => AppTuning { approx_bits: 12, power_reduction_pct: 100, trunc_bits: 12 },
    }
}

/// Tuning for a (kind, app) pair: PAM4 policies use the PAM4-swept table.
pub fn default_tuning(kind: PolicyKind, app: &str) -> AppTuning {
    match kind {
        PolicyKind::LoraxPam4 => table3_defaults_pam4(app),
        _ => table3_defaults(app),
    }
}

/// A fully-resolved policy for one application run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Policy {
    pub kind: PolicyKind,
    pub tuning: AppTuning,
}

impl Policy {
    pub fn new(kind: PolicyKind, app: &str) -> Policy {
        Policy { kind, tuning: default_tuning(kind, app) }
    }

    pub fn with_tuning(kind: PolicyKind, tuning: AppTuning) -> Policy {
        Policy { kind, tuning }
    }

    /// Number of approximable LSBs for this policy (0 = none).
    pub fn approx_bits(&self) -> u32 {
        match self.kind {
            PolicyKind::Baseline => 0,
            PolicyKind::Truncation => self.tuning.trunc_bits,
            PolicyKind::Prior16 => 16,
            PolicyKind::LoraxOok | PolicyKind::LoraxPam4 => self.tuning.approx_bits,
        }
    }

    /// Commanded LSB laser level *before* the loss-aware decision
    /// (the decision may turn it into 0 for far destinations).
    pub fn commanded_level(&self, pam4_power_factor: f64) -> f64 {
        match self.kind {
            PolicyKind::Baseline => 1.0,
            PolicyKind::Truncation => 0.0,
            PolicyKind::Prior16 => 0.2,
            PolicyKind::LoraxOok => self.tuning.level(),
            // §4.2: PAM4 cannot drop LSB power as low as OOK.
            PolicyKind::LoraxPam4 => (self.tuning.level() * pam4_power_factor).min(1.0),
        }
    }

    /// Does this policy consult the loss table per destination?
    pub fn loss_aware(&self) -> bool {
        matches!(self.kind, PolicyKind::LoraxOok | PolicyKind::LoraxPam4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table3_matches_paper() {
        let bs = paper_table3("blackscholes");
        assert_eq!((bs.approx_bits, bs.power_reduction_pct, bs.trunc_bits), (32, 90, 12));
        let fft = paper_table3("fft");
        assert_eq!((fft.approx_bits, fft.power_reduction_pct, fft.trunc_bits), (32, 50, 8));
        let jpeg = paper_table3("jpeg");
        assert_eq!((jpeg.approx_bits, jpeg.power_reduction_pct, jpeg.trunc_bits), (24, 80, 20));
        assert_eq!(paper_table3("canneal").power_reduction_pct, 100);
        assert_eq!(paper_table3("sobel").trunc_bits, 32);
        assert_eq!(paper_table3("streamcluster").approx_bits, 28);
    }

    #[test]
    fn our_defaults_exist_for_all_evaluated_apps() {
        for app in crate::apps::EVALUATED_APPS {
            let t = table3_defaults(app);
            assert!(t.approx_bits >= t.trunc_bits || app == "canneal", "{app}");
            assert!(t.approx_bits <= 32 && t.power_reduction_pct <= 100, "{app}");
        }
    }

    #[test]
    fn level_from_reduction() {
        let t = AppTuning { approx_bits: 32, power_reduction_pct: 80, trunc_bits: 0 };
        assert!((t.level() - 0.2).abs() < 1e-12);
        let t = AppTuning { approx_bits: 32, power_reduction_pct: 100, trunc_bits: 0 };
        assert_eq!(t.level(), 0.0);
    }

    #[test]
    fn policy_bits_per_kind() {
        let p = Policy::new(PolicyKind::Baseline, "fft");
        assert_eq!(p.approx_bits(), 0);
        let p = Policy::new(PolicyKind::Truncation, "fft");
        assert_eq!(p.approx_bits(), table3_defaults("fft").trunc_bits);
        let p = Policy::new(PolicyKind::Prior16, "fft");
        assert_eq!(p.approx_bits(), 16);
        let p = Policy::new(PolicyKind::LoraxOok, "fft");
        assert_eq!(p.approx_bits(), table3_defaults("fft").approx_bits);
    }

    #[test]
    fn commanded_levels() {
        let p = Policy::new(PolicyKind::Prior16, "fft");
        assert!((p.commanded_level(1.5) - 0.2).abs() < 1e-12);
        let t = AppTuning { approx_bits: 16, power_reduction_pct: 50, trunc_bits: 8 };
        let p = Policy::with_tuning(PolicyKind::LoraxOok, t);
        assert!((p.commanded_level(1.5) - 0.5).abs() < 1e-12);
        let p = Policy::with_tuning(PolicyKind::LoraxPam4, t); // 1.5x floor
        assert!((p.commanded_level(1.5) - 0.75).abs() < 1e-12);
        // PAM4 level saturates at full power.
        let p = Policy::with_tuning(
            PolicyKind::LoraxPam4,
            AppTuning { approx_bits: 32, power_reduction_pct: 10, trunc_bits: 0 },
        );
        assert_eq!(p.commanded_level(1.5), 1.0);
    }

    #[test]
    fn policy_kind_name_roundtrip() {
        for k in PolicyKind::ALL {
            assert_eq!(k.name().parse::<PolicyKind>().unwrap(), k);
            assert_eq!(k.to_string(), k.name());
        }
        assert_eq!("lorax-ook".parse::<PolicyKind>().unwrap(), PolicyKind::LoraxOok);
        let err = "nope".parse::<PolicyKind>().unwrap_err().to_string();
        assert!(err.contains("baseline"), "{err}");
    }

    #[test]
    fn modulation_only_pam4_differs() {
        assert_eq!(PolicyKind::LoraxPam4.modulation(), Modulation::Pam4);
        for k in [PolicyKind::Baseline, PolicyKind::Truncation, PolicyKind::Prior16, PolicyKind::LoraxOok] {
            assert_eq!(k.modulation(), Modulation::Ook);
        }
    }
}
