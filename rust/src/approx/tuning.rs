//! Application-specific sensitivity analysis (paper §5.2).
//!
//! [`sweep_app`] regenerates one Fig.-6 surface: application output error
//! (eq. 3) as a function of the number of approximated LSBs (4..32) and
//! the laser power reduction for those LSBs (0..100%), measured by
//! actually running the workload engine through the photonic channel at
//! every grid point.  [`select_tuning`] then performs the Table-3
//! search: the most aggressive (bits, power-reduction) pair that keeps
//! output error under the 10% threshold, preferring more approximated
//! bits first (more wavelengths eligible for power reduction), then more
//! reduction — the paper's ordering.

use crate::apps::{by_name_scaled, output_error_pct};
use crate::approx::channel::IdentityChannel;
use crate::approx::policy::{AppTuning, Policy, PolicyKind};
use crate::coordinator::channel::{NativeCorruptor, PhotonicChannel};
use crate::coordinator::gwi::GwiDecisionEngine;

/// One measured grid point of a sensitivity surface.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    /// Approximated LSBs at this point.
    pub bits: u32,
    /// Laser power reduction for those LSBs, percent.
    pub reduction_pct: u32,
    /// Measured output error (paper eq. 3), percent.
    pub error_pct: f64,
}

/// A full Fig.-6 surface for one application.
#[derive(Clone, Debug)]
pub struct SensitivitySurface {
    /// Application name.
    pub app: String,
    /// Error ceiling the Table-3 selection runs against, percent.
    pub threshold_pct: f64,
    /// Measured grid points, bits-major then reduction.
    pub points: Vec<SweepPoint>,
}

impl SensitivitySurface {
    /// The measured error at one grid point, if it was swept.
    pub fn error_at(&self, bits: u32, reduction_pct: u32) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.bits == bits && p.reduction_pct == reduction_pct)
            .map(|p| p.error_pct)
    }
}

/// The paper's Fig.-6 approximated-LSB-count axis.
pub const BITS_AXIS: [u32; 8] = [4, 8, 12, 16, 20, 24, 28, 32];
/// The paper's Fig.-6 laser-power-reduction axis, percent.
pub const REDUCTION_AXIS: [u32; 11] = [0, 10, 20, 30, 40, 50, 60, 70, 80, 90, 100];

/// Sweep one application over the (bits, reduction) grid.
///
/// `scale` shrinks the workload for fast runs (1.0 = the paper's "large
/// input" size); `kind` is the policy family being swept (LORAX-OOK by
/// default; PAM4 sweeps use the same grid).
pub fn sweep_app(
    engine: &GwiDecisionEngine,
    app: &str,
    kind: PolicyKind,
    seed: u64,
    scale: f64,
    bits_axis: &[u32],
    reduction_axis: &[u32],
) -> SensitivitySurface {
    let workload =
        by_name_scaled(app, seed, scale).unwrap_or_else(|| panic!("unknown app {app:?}"));
    // Golden run once.
    let mut golden_ch = IdentityChannel::new();
    let golden = workload.run(&mut golden_ch);

    let mut points = Vec::with_capacity(bits_axis.len() * reduction_axis.len());
    for &bits in bits_axis {
        for &red in reduction_axis {
            let tuning =
                AppTuning { approx_bits: bits, power_reduction_pct: red, trunc_bits: bits };
            let policy = Policy::with_tuning(kind, tuning);
            let mut ch = PhotonicChannel::new(engine, policy, NativeCorruptor, seed as u32);
            let out = workload.run(&mut ch);
            points.push(SweepPoint {
                bits,
                reduction_pct: red,
                error_pct: output_error_pct(&golden, &out),
            });
        }
    }
    SensitivitySurface { app: app.to_string(), threshold_pct: 10.0, points }
}

/// Table-3 selection from a measured surface: among grid points with
/// `error < threshold`, pick the one with the largest expected laser
/// saving.  Per-wavelength laser power scales linearly with the level,
/// so the saving on a float flit is proportional to
/// `bits x reduction_pct` — that product is the selection objective
/// (ties break toward more bits, then more reduction; the paper states
/// only "best combination", so we make the energy objective explicit).
/// `trunc_bits` is the largest truncatable count (reduction=100 column).
pub fn select_tuning(surface: &SensitivitySurface, threshold_pct: f64) -> AppTuning {
    let mut best: Option<(u32, u32)> = None;
    let score = |(b, r): (u32, u32)| (b * r, b, r);
    for p in &surface.points {
        if p.error_pct < threshold_pct {
            let cand = (p.bits, p.reduction_pct);
            best = Some(match best {
                None => cand,
                Some(cur) => {
                    if score(cand) > score(cur) {
                        cand
                    } else {
                        cur
                    }
                }
            });
        }
    }
    let (approx_bits, power_reduction_pct) = best.unwrap_or((0, 0));
    let trunc_bits = surface
        .points
        .iter()
        .filter(|p| p.reduction_pct == 100 && p.error_pct < threshold_pct)
        .map(|p| p.bits)
        .max()
        .unwrap_or(0);
    AppTuning { approx_bits, power_reduction_pct, trunc_bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phys::params::{Modulation, PhotonicParams};
    use crate::topology::clos::ClosTopology;

    fn engine() -> GwiDecisionEngine {
        GwiDecisionEngine::new(
            ClosTopology::default_64core(),
            PhotonicParams::default(),
            Modulation::OOK,
        )
    }

    #[test]
    fn sweep_corner_cases() {
        let e = engine();
        // Tiny grid on a tolerant app to keep the test fast.
        let s = sweep_app(&e, "sobel", PolicyKind::LORAX_OOK, 3, 0.02, &[4, 32], &[0, 100]);
        assert_eq!(s.points.len(), 4);
        // Zero reduction at full detectability = error-free channel.
        let e_0 = s.error_at(4, 0).unwrap();
        assert!(e_0 < 1e-9, "4 bits @ 0% should be error-free, got {e_0}");
        // Full truncation of 32 bits must dominate 4 bits truncated.
        let e_4_100 = s.error_at(4, 100).unwrap();
        let e_32_100 = s.error_at(32, 100).unwrap();
        assert!(e_32_100 >= e_4_100, "{e_32_100} !>= {e_4_100}");
    }

    #[test]
    fn selection_maximizes_laser_saving_product() {
        let surface = SensitivitySurface {
            app: "synthetic".into(),
            threshold_pct: 10.0,
            points: vec![
                SweepPoint { bits: 16, reduction_pct: 100, error_pct: 2.0 }, // 1600
                SweepPoint { bits: 32, reduction_pct: 50, error_pct: 8.0 },  // 1600 (more bits)
                SweepPoint { bits: 32, reduction_pct: 80, error_pct: 12.0 }, // infeasible
                SweepPoint { bits: 24, reduction_pct: 90, error_pct: 4.0 },  // 2160 <- winner
            ],
        };
        let t = select_tuning(&surface, 10.0);
        assert_eq!(t.approx_bits, 24);
        assert_eq!(t.power_reduction_pct, 90);
        assert_eq!(t.trunc_bits, 16);
    }

    #[test]
    fn selection_ties_break_toward_more_bits() {
        let surface = SensitivitySurface {
            app: "synthetic".into(),
            threshold_pct: 10.0,
            points: vec![
                SweepPoint { bits: 16, reduction_pct: 100, error_pct: 2.0 },
                SweepPoint { bits: 32, reduction_pct: 50, error_pct: 8.0 },
            ],
        };
        let t = select_tuning(&surface, 10.0);
        assert_eq!((t.approx_bits, t.power_reduction_pct), (32, 50));
    }

    #[test]
    fn selection_with_nothing_feasible() {
        let surface = SensitivitySurface {
            app: "x".into(),
            threshold_pct: 10.0,
            points: vec![SweepPoint { bits: 4, reduction_pct: 10, error_pct: 50.0 }],
        };
        let t = select_tuning(&surface, 10.0);
        assert_eq!((t.approx_bits, t.power_reduction_pct, t.trunc_bits), (0, 0, 0));
    }
}
