//! The [`Channel`] abstraction: how workload engines communicate.
//!
//! Engines are written against this trait; the backend decides what
//! happens to the bits.  [`IdentityChannel`] delivers everything intact
//! (the golden run, and the Fig.-2 characterization counter);
//! [`crate::coordinator::PhotonicChannel`] applies the full LORAX
//! decision + corruption model, natively or through the AOT/PJRT
//! executable.  Output error (paper eq. 3) is always *measured* by
//! running the same engine over both backends.

use super::policy::TransferMode;
use crate::topology::clos::NodeId;
use crate::traffic::packet::{Packet, PayloadKind, TrafficProfile, LINE_WORDS};
use crate::traffic::trace::TraceRecord;

/// Word-level accounting of what the channel did to float payloads.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChannelStats {
    /// Per-kind packet/word counters (Fig. 2 data).
    pub profile: TrafficProfile,
    /// Total transfers (any kind).
    pub transfers: u64,
    /// Doubles that crossed a photonic link with LSBs at reduced power.
    pub values_reduced: u64,
    /// Doubles that crossed with LSBs truncated.
    pub values_truncated: u64,
    /// Doubles delivered fully intact.
    pub values_exact: u64,
}

impl ChannelStats {
    /// Count `values` doubles delivered under `mode`.
    pub fn record_mode(&mut self, mode: TransferMode, values: u64) {
        match mode {
            TransferMode::FullPower => self.values_exact += values,
            TransferMode::Reduced { .. } => self.values_reduced += values,
            TransferMode::Truncated => self.values_truncated += values,
        }
    }
}

/// Transport abstraction the workload engines call into.
pub trait Channel {
    /// Move `data` from `src` to `dst`, mutating it per the channel model
    /// when `approximable` and the active policy allow.
    fn send_f64(&mut self, src: NodeId, dst: NodeId, data: &mut [f64], approximable: bool);

    /// Integer payload: counted and charged, never approximated.
    fn send_ints(&mut self, src: NodeId, dst: NodeId, words: usize);

    /// Control/coherence message of `words` payload words.
    fn send_control(&mut self, src: NodeId, dst: NodeId, words: u32);

    /// Word-level accounting of everything sent so far.
    fn stats(&self) -> &ChannelStats;

    /// Drain the recorded trace (for NoC replay).
    fn take_trace(&mut self) -> Vec<TraceRecord>;
}

/// Split a payload of `words` 32-bit words into cache-line packets and
/// record them.  Returns the number of packets.
pub(crate) fn packetize(
    profile: &mut TrafficProfile,
    trace: &mut Vec<TraceRecord>,
    clock: &mut u64,
    src: NodeId,
    dst: NodeId,
    kind: PayloadKind,
    words: usize,
    approximable: bool,
) -> u32 {
    let mut emit = |payload: u32, clock: &mut u64| {
        let pkt = Packet { src, dst, kind, payload_words: payload, approximable };
        profile.record(&pkt);
        trace.push(TraceRecord { inject_cycle: *clock, packet: pkt });
        *clock += 1;
    };
    if kind == PayloadKind::Control {
        emit(words as u32, clock);
        return 1;
    }
    let mut remaining = words as u32;
    let mut packets = 0;
    while remaining > 0 {
        let take = remaining.min(LINE_WORDS);
        emit(take, clock);
        remaining -= take;
        packets += 1;
    }
    packets
}

/// Golden channel: perfect delivery, full accounting.
#[derive(Default)]
pub struct IdentityChannel {
    stats: ChannelStats,
    trace: Vec<TraceRecord>,
    clock: u64,
}

impl IdentityChannel {
    /// A fresh golden channel.
    pub fn new() -> IdentityChannel {
        IdentityChannel::default()
    }
}

impl Channel for IdentityChannel {
    fn send_f64(&mut self, src: NodeId, dst: NodeId, data: &mut [f64], approximable: bool) {
        self.stats.transfers += 1;
        self.stats.values_exact += data.len() as u64;
        // The wire carries IEEE-754 single precision (DESIGN.md §5):
        // even the golden channel pays the SP quantization, so output
        // error measures *corruption*, not float rounding.
        for v in data.iter_mut() {
            *v = *v as f32 as f64;
        }
        packetize(
            &mut self.stats.profile,
            &mut self.trace,
            &mut self.clock,
            src,
            dst,
            PayloadKind::Float64,
            data.len(),
            approximable,
        );
    }

    fn send_ints(&mut self, src: NodeId, dst: NodeId, words: usize) {
        self.stats.transfers += 1;
        packetize(
            &mut self.stats.profile,
            &mut self.trace,
            &mut self.clock,
            src,
            dst,
            PayloadKind::Int,
            words,
            false,
        );
    }

    fn send_control(&mut self, src: NodeId, dst: NodeId, words: u32) {
        self.stats.transfers += 1;
        packetize(
            &mut self.stats.profile,
            &mut self.trace,
            &mut self.clock,
            src,
            dst,
            PayloadKind::Control,
            words as usize,
            false,
        );
    }

    fn stats(&self) -> &ChannelStats {
        &self.stats
    }

    fn take_trace(&mut self) -> Vec<TraceRecord> {
        std::mem::take(&mut self.trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_preserves_data() {
        let mut ch = IdentityChannel::new();
        let mut xs = vec![1.0f64, -2.5, 3.25];
        let before = xs.clone();
        ch.send_f64(NodeId::Core(0), NodeId::Core(9), &mut xs, true);
        assert_eq!(xs, before);
        assert_eq!(ch.stats().values_exact, 3);
    }

    #[test]
    fn packetization_line_granularity() {
        let mut ch = IdentityChannel::new();
        // 20 values = 20 SP words = 1 full line (16) + 1 partial (4).
        let mut xs = vec![0.5f64; 20];
        ch.send_f64(NodeId::Core(0), NodeId::Core(9), &mut xs, true);
        assert_eq!(ch.stats().profile.float_packets, 2);
        assert_eq!(ch.stats().profile.float_words, 20);
        let trace = ch.take_trace();
        assert_eq!(trace.len(), 2);
        assert_eq!(trace[0].packet.payload_words, 16);
        assert_eq!(trace[1].packet.payload_words, 4);
    }

    #[test]
    fn identity_pays_sp_quantization_only() {
        let mut ch = IdentityChannel::new();
        let mut xs = vec![std::f64::consts::PI, 1.0e-40, -7.25];
        ch.send_f64(NodeId::Core(0), NodeId::Core(9), &mut xs, true);
        assert_eq!(xs[0], std::f64::consts::PI as f32 as f64);
        assert_eq!(xs[2], -7.25); // exactly representable in f32
    }

    #[test]
    fn int_and_control_counted_separately() {
        let mut ch = IdentityChannel::new();
        ch.send_ints(NodeId::Core(0), NodeId::Core(1), 16);
        ch.send_control(NodeId::Core(1), NodeId::Core(0), 2);
        let p = &ch.stats().profile;
        assert_eq!(p.int_packets, 1);
        assert_eq!(p.control_packets, 1);
        assert_eq!(p.float_packets, 0);
        assert_eq!(ch.stats().transfers, 2);
    }

    #[test]
    fn trace_drain_resets() {
        let mut ch = IdentityChannel::new();
        ch.send_ints(NodeId::Core(0), NodeId::Core(1), 4);
        assert_eq!(ch.take_trace().len(), 1);
        assert!(ch.take_trace().is_empty());
    }
}
