//! IEEE-754 word manipulation and the **native corruption kernel**.
//!
//! This is the Rust twin of the Layer-1 Pallas kernel
//! (`python/compile/kernels/lorax_approx.py`): same counter-based RNG,
//! same thresholds semantics, bit-identical outputs.  The coordinator uses
//! it as the in-process hot path; `runtime::channel_exec` routes the same
//! arrays through the AOT HLO executable, and the integration tests assert
//! the two agree word-for-word.
//!
//! Word layout convention (shared with the AOT path): the PNoC wire
//! carries IEEE-754 *single precision* (DESIGN.md §5) — a transfer of
//! `n` values is `n` u32 words, and the word index within the transfer
//! keys the RNG, so any batching produces the same corruption.  A
//! double-precision `[lo, hi]` variant is retained below for the DP
//! channel mode and its tests.

use crate::util::rng::{bit_rand, make_word_key, ALWAYS};

/// Bit mask selecting the `bits` least-significant bits of the low word
/// of a double (the paper's "number of approximated LSBs", 0..=32).
#[inline]
pub fn mask_for_lsbs(bits: u32) -> u32 {
    match bits {
        0 => 0,
        32.. => u32::MAX,
        b => (1u32 << b) - 1,
    }
}

/// Corrupt one word through the photonic channel model.
///
/// * `mask` — bits carried on reduced/zero-power wavelengths;
/// * `t10`/`t01` — error thresholds (probability x 2^32; `ALWAYS` = 1.0);
/// * `key` — per-word RNG key from [`make_word_key`].
#[inline]
pub fn corrupt_word(word: u32, mask: u32, t10: u32, t01: u32, key: u32) -> u32 {
    if mask == 0 || (t10 == 0 && t01 == 0) {
        return word; // error-free fast path
    }
    if t10 == ALWAYS && t01 == 0 {
        return word & !mask; // exact truncation fast path
    }
    let mut out = word & !mask;
    let mut m = mask;
    while m != 0 {
        let b = m.trailing_zeros();
        m &= m - 1;
        let r = bit_rand(key, b);
        let sent_one = (word >> b) & 1 == 1;
        let recv_one = if sent_one {
            !(r < t10 || t10 == ALWAYS)
        } else {
            r < t01 || t01 == ALWAYS
        };
        if recv_one {
            out |= 1 << b;
        }
    }
    out
}

/// Branch-free variant of [`corrupt_word`]: draws the uniform for every
/// masked bit in one pass, accumulates the `1→0` and `0→1` flip masks,
/// and composes the received word with mask arithmetic instead of
/// per-bit conditionals.  Bit-identical to [`corrupt_word`] for every
/// input (property-tested in `tests/properties.rs`); callers processing
/// a whole transfer should dispatch the identity/truncation fast paths
/// once per transfer (as [`corrupt_f32_words`] does) and use this only
/// in the stochastic regime.
#[inline]
pub fn corrupt_word_fast(word: u32, mask: u32, t10: u32, t01: u32, key: u32) -> u32 {
    let t10_always = (t10 == ALWAYS) as u32;
    let t01_always = (t01 == ALWAYS) as u32;
    let mut flip10 = 0u32; // masked bits where a sent '1' arrives as '0'
    let mut set01 = 0u32; // masked bits where a sent '0' arrives as '1'
    let mut m = mask;
    while m != 0 {
        let b = m.trailing_zeros();
        m &= m - 1;
        let r = bit_rand(key, b);
        flip10 |= (((r < t10) as u32) | t10_always) << b;
        set01 |= (((r < t01) as u32) | t01_always) << b;
    }
    let recv = (word & !flip10) | (!word & set01);
    (word & !mask) | (recv & mask)
}

/// Corrupt a full word array with per-word parameters (the exact
/// signature of the AOT `channel` artifact, for cross-validation).
/// Per-word parameters defeat transfer-level dispatch, so each word
/// goes through the branch-free [`corrupt_word_fast`] (identity words
/// short-circuit on their own).
pub fn corrupt_words(
    words: &mut [u32],
    masks: &[u32],
    t10s: &[u32],
    t01s: &[u32],
    keys: &[u32],
) {
    assert!(
        words.len() == masks.len()
            && words.len() == t10s.len()
            && words.len() == t01s.len()
            && words.len() == keys.len()
    );
    for i in 0..words.len() {
        if masks[i] == 0 || (t10s[i] == 0 && t01s[i] == 0) {
            continue;
        }
        words[i] = corrupt_word_fast(words[i], masks[i], t10s[i], t01s[i], keys[i]);
    }
}

/// Corrupt the low words of a double-precision payload in place.
///
/// `mask`/`t10`/`t01` apply to every value's low word (high words ride
/// full-power wavelengths and are untouched); `seed` identifies the
/// transfer; word indices follow the shared layout convention.  The
/// identity fast path dispatches once per transfer; remaining regimes
/// run the branch-free [`corrupt_word_fast`] per low word.
pub fn corrupt_f64_slice(data: &mut [f64], mask: u32, t10: u32, t01: u32, seed: u32) {
    if mask == 0 || (t10 == 0 && t01 == 0) {
        return;
    }
    for (i, v) in data.iter_mut().enumerate() {
        let bits = v.to_bits();
        let lo = bits as u32;
        let key = make_word_key(seed, (2 * i) as u32);
        let lo2 = corrupt_word_fast(lo, mask, t10, t01, key);
        if lo2 != lo {
            *v = f64::from_bits((bits & 0xFFFF_FFFF_0000_0000) | lo2 as u64);
        }
    }
}

/// Convert a compute-side f64 payload to the single-precision wire
/// format: one u32 word per value (see DESIGN.md §5 — the paper's
/// 4..32-LSB axis spans a whole SP word, so the PNoC carries floats as
/// IEEE-754 single precision; word index == value index keys the RNG).
pub fn f64s_to_f32_words(data: &[f64]) -> Vec<u32> {
    data.iter().map(|v| (*v as f32).to_bits()).collect()
}

/// Inverse of [`f64s_to_f32_words`] (back to compute precision).
pub fn f32_words_to_f64s(words: &[u32]) -> Vec<f64> {
    words.iter().map(|w| f32::from_bits(*w) as f64).collect()
}

/// Corrupt a single-precision wire payload in place: every word gets the
/// same (mask, thresholds); keys come from the word index within the
/// transfer.
///
/// Thin one-shot wrapper over the batched kernel: it resolves a
/// [`KernelDescriptor`](crate::approx::kernel::KernelDescriptor) for the
/// triple and runs it once.  Hot-path callers that reuse a (policy,
/// tuning, modulation) decision across transfers should build the
/// descriptor once (see [`crate::coordinator::gwi::KernelTable`]) and
/// call [`crate::approx::kernel::corrupt_words_batched`] per transfer
/// instead, skipping the regime dispatch and masked-bit enumeration
/// entirely.  Bit-for-bit identical to the scalar [`corrupt_word`] /
/// [`corrupt_word_fast`] (property-tested, plus the differential
/// harness in `tests/differential_kernels.rs`) and to the Pallas
/// kernel.
pub fn corrupt_f32_words(words: &mut [u32], mask: u32, t10: u32, t01: u32, seed: u32) {
    if mask == 0 || (t10 == 0 && t01 == 0) {
        return; // error-free: skip even the descriptor build
    }
    crate::approx::kernel::KernelDescriptor::new(mask, t10, t01).corrupt(words, seed);
}

/// The per-word scalar reference kernel: [`corrupt_word`] applied to
/// every word with its transfer-indexed key, no transfer-level dispatch,
/// no batching.  This is the **oracle** the batched path is pinned
/// byte-identical against (differential harness + property tests), and
/// what `LORAX_KERNEL=scalar` routes the whole stack through for
/// bisection (see [`crate::approx::kernel::kernel_mode`]).
pub fn corrupt_words_scalar(words: &mut [u32], mask: u32, t10: u32, t01: u32, seed: u32) {
    for (i, w) in words.iter_mut().enumerate() {
        *w = corrupt_word(*w, mask, t10, t01, make_word_key(seed, i as u32));
    }
}

/// Flatten doubles to the double-precision `[lo, hi]` word layout
/// (retained for the DP variant of the channel and its tests).
pub fn f64s_to_words(data: &[f64]) -> Vec<u32> {
    let mut out = Vec::with_capacity(data.len() * 2);
    for v in data {
        let bits = v.to_bits();
        out.push(bits as u32);
        out.push((bits >> 32) as u32);
    }
    out
}

/// Inverse of [`f64s_to_words`].
pub fn words_to_f64s(words: &[u32]) -> Vec<f64> {
    assert!(words.len() % 2 == 0);
    words
        .chunks_exact(2)
        .map(|c| f64::from_bits((c[1] as u64) << 32 | c[0] as u64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn mask_edges() {
        assert_eq!(mask_for_lsbs(0), 0);
        assert_eq!(mask_for_lsbs(1), 1);
        assert_eq!(mask_for_lsbs(16), 0xFFFF);
        assert_eq!(mask_for_lsbs(31), 0x7FFF_FFFF);
        assert_eq!(mask_for_lsbs(32), u32::MAX);
        assert_eq!(mask_for_lsbs(40), u32::MAX);
    }

    #[test]
    fn golden_vs_python_oracle() {
        // Generated with python/compile/kernels/ref.py:
        //   words=[0xDEADBEEF, 0x12345678, 0xFFFFFFFF, 0x00000000]
        //   mask=0x0000FFFF t10=0x40000000 t01=0x00100000 seed=123
        //   keys=make_word_keys_np(123, [0,1,2,3])
        // (regenerate: python -c "...", see rust/tests/integration_runtime.rs)
        let seed = 123u32;
        let words = [0xDEAD_BEEFu32, 0x1234_5678, 0xFFFF_FFFF, 0x0000_0000];
        let expected = python_oracle_golden();
        for (i, (&w, &e)) in words.iter().zip(expected.iter()).enumerate() {
            let key = make_word_key(seed, i as u32);
            let got = corrupt_word(w, 0x0000_FFFF, 0x4000_0000, 0x0010_0000, key);
            assert_eq!(got, e, "word {i}: got {got:#x} want {e:#x}");
        }
    }

    // Filled in from the python oracle (see integration_runtime test which
    // revalidates the same vectors through the AOT artifact).
    fn python_oracle_golden() -> [u32; 4] {
        [0xDEAD_BEE7, 0x1234_5660, 0xFFFF_BDEA, 0x0000_0000]
    }

    #[test]
    fn truncation_and_identity_fast_paths() {
        check("trunc-identity", 64, |g| {
            let w = g.u32();
            let mask = g.u32();
            let key = make_word_key(g.u32(), 0);
            assert_eq!(corrupt_word(w, mask, ALWAYS, 0, key), w & !mask);
            assert_eq!(corrupt_word(w, mask, 0, 0, key), w);
            assert_eq!(corrupt_word(w, 0, g.u32(), g.u32(), key), w);
        });
    }

    #[test]
    fn bits_outside_mask_never_change() {
        check("msb-preserved", 64, |g| {
            let w = g.u32();
            let mask = g.u32();
            let out = corrupt_word(w, mask, g.u32(), g.u32(), make_word_key(g.u32(), g.u32()));
            assert_eq!(out & !mask, w & !mask);
        });
    }

    #[test]
    fn always_thresholds_saturate() {
        check("always-saturates", 32, |g| {
            let w = g.u32();
            let mask = g.u32();
            let key = make_word_key(g.u32(), 1);
            // t10 = t01 = ALWAYS: every masked bit inverts.
            let out = corrupt_word(w, mask, ALWAYS, ALWAYS, key);
            assert_eq!(out, (w & !mask) | (!w & mask));
        });
    }

    #[test]
    fn f64_layout_roundtrip() {
        check("f64-words-roundtrip", 32, |g| {
            let xs = g.vec(17, |g| g.interesting_f64());
            let words = f64s_to_words(&xs);
            assert_eq!(words.len(), 34);
            let back = words_to_f64s(&words);
            for (a, b) in xs.iter().zip(back.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn slice_corruption_matches_word_corruption() {
        check("slice-vs-word", 32, |g| {
            let seed = g.u32();
            let mask = mask_for_lsbs(g.usize(1, 32) as u32);
            let t10 = g.u32();
            let mut xs = g.vec(9, |g| g.interesting_f64());
            let mut words = f64s_to_words(&xs);
            corrupt_f64_slice(&mut xs, mask, t10, 0, seed);
            for i in 0..words.len() / 2 {
                let key = make_word_key(seed, (2 * i) as u32);
                words[2 * i] = corrupt_word(words[2 * i], mask, t10, 0, key);
            }
            let back = words_to_f64s(&words);
            for (a, b) in xs.iter().zip(back.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn vectorized_equals_scalar_kernel() {
        check("vectorized-vs-scalar", 48, |g| {
            let n = g.usize(1, 1200); // crosses the 512-word chunk boundary
            let mask = if g.bool() { mask_for_lsbs(g.usize(1, 32) as u32) } else { g.u32() };
            let (t10, t01, seed) = (g.u32(), g.u32(), g.u32());
            let mut words: Vec<u32> = g.vec(n, |g| g.u32());
            let expect: Vec<u32> = words
                .iter()
                .enumerate()
                .map(|(i, w)| corrupt_word(*w, mask, t10, t01, make_word_key(seed, i as u32)))
                .collect();
            corrupt_f32_words(&mut words, mask, t10, t01, seed);
            assert_eq!(words, expect);
        });
    }

    // corrupt_word_fast == corrupt_word equivalence lives in
    // tests/properties.rs (prop_corrupt_word_fast_matches_reference),
    // which covers a strictly wider input domain than a copy here
    // would.

    #[test]
    fn vectorized_extreme_thresholds() {
        for (t10, t01) in [(0u32, 0u32), (ALWAYS, 0), (0, ALWAYS), (ALWAYS, ALWAYS)] {
            let mut words: Vec<u32> = (0..700u32).map(|i| i.wrapping_mul(0x9E37_79B9)).collect();
            let expect: Vec<u32> = words
                .iter()
                .enumerate()
                .map(|(i, w)| corrupt_word(*w, 0xFFFF, t10, t01, make_word_key(5, i as u32)))
                .collect();
            corrupt_f32_words(&mut words, 0xFFFF, t10, t01, 5);
            assert_eq!(words, expect, "t10={t10:#x} t01={t01:#x}");
        }
    }

    #[test]
    fn high_word_of_double_untouched() {
        let mut xs: Vec<f64> = vec![1.5e300, -2.25, 3.14159, 1e-300];
        let before: Vec<u64> = xs.iter().map(|v| v.to_bits()).collect();
        corrupt_f64_slice(&mut xs, u32::MAX, ALWAYS, 0, 7);
        for (v, b) in xs.iter().zip(before.iter()) {
            assert_eq!(v.to_bits() >> 32, b >> 32);
            assert_eq!(v.to_bits() as u32, 0); // low word truncated
        }
    }
}
