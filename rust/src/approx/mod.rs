//! Approximation machinery: IEEE-754 bit manipulation, the native
//! corruption kernel (bit-identical to the Layer-1 Pallas kernel), the
//! [`Channel`] abstraction workloads communicate through, the five
//! approximation policies the paper compares, and the application-specific
//! tuning search behind Table 3.

pub mod channel;
pub mod float_bits;
pub mod kernel;
pub mod policy;
pub mod tuning;

pub use channel::{Channel, ChannelStats, IdentityChannel};
pub use float_bits::{corrupt_f64_slice, corrupt_word, corrupt_word_fast, mask_for_lsbs};
pub use kernel::{corrupt_words_batched, kernel_mode, KernelDescriptor, KernelMode, KernelRegime};
pub use policy::{AppTuning, Policy, PolicyKind, TransferMode};
