//! Precomputed, batched corruption kernels — the transfer-granular hot
//! path behind [`crate::approx::float_bits::corrupt_f32_words`].
//!
//! The per-transfer regime dispatch (identity / truncation / inversion /
//! stochastic, plus the Gray-threshold parameters the GWI decision
//! resolved from [`crate::phys::signaling::SignalingScheme`]) is hoisted
//! **out of the corruption call entirely** into a [`KernelDescriptor`]:
//! one immutable value per (policy, tuning, modulation) decision that
//! callers build once — next to the decision itself — and reuse for
//! every transfer (see [`crate::coordinator::gwi::KernelTable`] and the
//! descriptor cache inside
//! [`crate::coordinator::channel::PhotonicChannel`]).
//!
//! [`KernelDescriptor::corrupt`] then processes the whole transfer in
//! wide lanes:
//!
//! * **Truncate/Invert** pack adjacent u32 wire words into u64 pairs and
//!   apply one doubled mask per lane (registry-free `std` only — no
//!   `std::simd` nightly feature needed);
//! * the **stochastic** regimes run bit-major over 512-word chunks with
//!   branchless inner loops (LLVM auto-vectorizes the `fmix32` +
//!   compare + select across words), iterating a *precomputed* list of
//!   masked bit positions and their RNG salts instead of re-walking
//!   `trailing_zeros` per chunk.
//!
//! **Bit-identity contract:** every regime is byte-identical to the
//! per-word scalar oracle
//! ([`crate::approx::float_bits::corrupt_word`] /
//! [`corrupt_words_scalar`](crate::approx::float_bits::corrupt_words_scalar)),
//! because the RNG is keyed by absolute word index within the transfer
//! and each masked bit contributes an independent `acc |=` term — lane
//! packing and bit reordering cannot change outcomes.  The differential
//! harness (`tests/differential_kernels.rs`) pins this across all
//! modulations × the paper's five policies × edge payloads × ragged
//! lengths, and `LORAX_KERNEL=scalar` (see [`kernel_mode`]) keeps the
//! oracle runnable end-to-end for bisection.

use std::sync::OnceLock;

use crate::util::rng::{make_word_key, ALWAYS, GOLDEN};

/// Which corruption regime a (mask, t10, t01) triple resolves to.
///
/// Resolved once per descriptor — the per-word kernel never re-examines
/// the thresholds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelRegime {
    /// No masked bits, or both thresholds zero: words pass unchanged.
    Identity,
    /// `t10 == ALWAYS && t01 == 0`: masked bits read as 0 (wavelengths
    /// off) — pure mask AND, no RNG.
    Truncate,
    /// `t10 == t01 == ALWAYS`: every masked bit inverts — pure mask
    /// XOR, no RNG.
    Invert,
    /// Stochastic with `t01 == 0` (reduced-power LSBs, no 0→1 noise):
    /// the tighter `sent & keep` inner loop.
    ReducedNoSet,
    /// General stochastic regime (both thresholds in play).
    Stochastic,
}

/// A fully-resolved corruption kernel for one transfer class: the
/// (mask, thresholds) triple of a GWI decision plus everything the
/// batched kernel precomputes from it — regime, masked-bit list with
/// RNG salts, and the replay-side quality-loss proxy.
///
/// `Copy` by design: descriptors are small immutable values cached in
/// dense tables ([`crate::coordinator::gwi::KernelTable`]) and inline
/// arrays, exactly like [`crate::coordinator::gwi::Decision`].
#[derive(Clone, Copy, Debug)]
pub struct KernelDescriptor {
    /// Low-word mask of approximated bits.
    pub mask: u32,
    /// 1→0 flip threshold for the masked bits (probability × 2^32).
    pub t10: u32,
    /// 0→1 flip threshold for the masked bits (probability × 2^32).
    pub t01: u32,
    /// The regime the thresholds resolve to (dispatch hoisted here).
    pub regime: KernelRegime,
    /// Replay-side quality-loss proxy in [0, 1]:
    /// `popcount(mask)/32 × t10/ALWAYS`.  Bit-exact equal to
    /// [`crate::noc::sim::quality_loss_fraction`] for every decision the
    /// GWI engine produces (full-power decisions carry `mask == 0`;
    /// truncated ones carry `t10 == ALWAYS`, and `x × 1.0 == x` exactly
    /// in f64) — pinned by `tests/differential_kernels.rs`.
    pub quality_loss: f64,
    /// Number of masked bits (valid prefix of `bit_pos`/`bit_salt`).
    n_bits: u8,
    /// Masked bit positions, ascending.
    bit_pos: [u8; 32],
    /// Per-bit RNG salts: `(b + 1) * GOLDEN`, precomputed.
    bit_salt: [u32; 32],
}

impl KernelDescriptor {
    /// The do-nothing kernel (what a full-power decision runs).
    pub const IDENTITY: KernelDescriptor = KernelDescriptor {
        mask: 0,
        t10: 0,
        t01: 0,
        regime: KernelRegime::Identity,
        quality_loss: 0.0,
        n_bits: 0,
        bit_pos: [0; 32],
        bit_salt: [0; 32],
    };

    /// Resolve `(mask, t10, t01)` into a ready-to-run kernel: regime
    /// dispatch, masked-bit enumeration and RNG salts all happen here,
    /// once, instead of inside every transfer.
    pub fn new(mask: u32, t10: u32, t01: u32) -> KernelDescriptor {
        let regime = if mask == 0 || (t10 == 0 && t01 == 0) {
            KernelRegime::Identity
        } else if t10 == ALWAYS && t01 == 0 {
            KernelRegime::Truncate
        } else if t10 == ALWAYS && t01 == ALWAYS {
            KernelRegime::Invert
        } else if t01 == 0 {
            KernelRegime::ReducedNoSet
        } else {
            KernelRegime::Stochastic
        };
        let mut bit_pos = [0u8; 32];
        let mut bit_salt = [0u32; 32];
        let mut n_bits = 0u8;
        if matches!(regime, KernelRegime::ReducedNoSet | KernelRegime::Stochastic) {
            let mut m = mask;
            while m != 0 {
                let b = m.trailing_zeros();
                m &= m - 1;
                bit_pos[n_bits as usize] = b as u8;
                bit_salt[n_bits as usize] = (b + 1).wrapping_mul(GOLDEN);
                n_bits += 1;
            }
        }
        let quality_loss = (mask.count_ones() as f64 / 32.0) * (t10 as f64 / ALWAYS as f64);
        KernelDescriptor { mask, t10, t01, regime, quality_loss, n_bits, bit_pos, bit_salt }
    }

    /// Corrupt a whole transfer in place — the batched hot path.
    ///
    /// Bit-identical to running the scalar oracle per word with keys
    /// `make_word_key(seed, index)` (see the module-level contract).
    pub fn corrupt(&self, words: &mut [u32], seed: u32) {
        match self.regime {
            KernelRegime::Identity => {}
            KernelRegime::Truncate => {
                let keep = !self.mask;
                let keep64 = (keep as u64) << 32 | keep as u64;
                let mut lanes = words.chunks_exact_mut(2);
                for pair in lanes.by_ref() {
                    let v = ((pair[1] as u64) << 32 | pair[0] as u64) & keep64;
                    pair[0] = v as u32;
                    pair[1] = (v >> 32) as u32;
                }
                for w in lanes.into_remainder() {
                    *w &= keep;
                }
            }
            KernelRegime::Invert => {
                // `(w & !mask) | (!w & mask)` is `w ^ mask`.
                let mask64 = (self.mask as u64) << 32 | self.mask as u64;
                let mut lanes = words.chunks_exact_mut(2);
                for pair in lanes.by_ref() {
                    let v = ((pair[1] as u64) << 32 | pair[0] as u64) ^ mask64;
                    pair[0] = v as u32;
                    pair[1] = (v >> 32) as u32;
                }
                for w in lanes.into_remainder() {
                    *w ^= self.mask;
                }
            }
            KernelRegime::ReducedNoSet | KernelRegime::Stochastic => {
                self.corrupt_stochastic(words, seed);
            }
        }
    }

    /// The stochastic regimes: bit-major over 512-word chunks, iterating
    /// the precomputed masked-bit list.  Same keys, same per-bit salts
    /// and same `acc |=` composition as the historical transfer kernel,
    /// so outputs are byte-identical by construction.
    fn corrupt_stochastic(&self, words: &mut [u32], seed: u32) {
        const CHUNK: usize = 512;
        let t10 = self.t10;
        let t01 = self.t01;
        let mask = self.mask;
        let t10_always = (t10 == ALWAYS) as u32;
        let t01_always = (t01 == ALWAYS) as u32;
        let t01_zero = t01 == 0;
        let bits = &self.bit_pos[..self.n_bits as usize];
        let salts = &self.bit_salt[..self.n_bits as usize];
        let mut keys = [0u32; CHUNK];
        let mut acc = [0u32; CHUNK];
        let n = words.len();
        let mut start = 0;
        while start < n {
            let m = CHUNK.min(n - start);
            for (j, k) in keys[..m].iter_mut().enumerate() {
                *k = make_word_key(seed, (start + j) as u32);
            }
            for a in acc[..m].iter_mut() {
                *a = 0;
            }
            for (&b, &cb) in bits.iter().zip(salts.iter()) {
                let b = b as u32;
                let chunk = &words[start..start + m];
                if t01_zero {
                    // Sent '0' bits can never flip to '1': the received
                    // bit is `sent & (r >= t10)` — fewer ops per lane.
                    for j in 0..m {
                        let r = fmix32_inline(keys[j] ^ cb);
                        let sent = (chunk[j] >> b) & 1;
                        let keep = ((r >= t10) as u32) & (t10_always ^ 1);
                        acc[j] |= (sent & keep) << b;
                    }
                } else {
                    for j in 0..m {
                        let r = fmix32_inline(keys[j] ^ cb);
                        let sent = (chunk[j] >> b) & 1;
                        let flip10 = ((r < t10) as u32) | t10_always;
                        let set01 = ((r < t01) as u32) | t01_always;
                        let recv1 = (sent & (flip10 ^ 1)) | ((sent ^ 1) & set01);
                        acc[j] |= recv1 << b;
                    }
                }
            }
            for j in 0..m {
                words[start + j] = (words[start + j] & !mask) | acc[j];
            }
            start += m;
        }
    }
}

/// Batched transfer corruption through a prebuilt descriptor — the
/// entry point `Simulator`-side callers use once per transfer after
/// hoisting [`KernelDescriptor::new`] out of the loop.
#[inline]
pub fn corrupt_words_batched(words: &mut [u32], desc: &KernelDescriptor, seed: u32) {
    desc.corrupt(words, seed);
}

/// Which kernel implementation the in-process corruption path runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// The batched wide-lane kernel (default).
    Batched,
    /// The per-word scalar oracle — the bisection escape hatch
    /// (`LORAX_KERNEL=scalar`), byte-identical by contract.
    Scalar,
}

/// Process-wide kernel selection, read once from `LORAX_KERNEL`
/// (`"scalar"` selects the oracle; anything else — including unset —
/// selects the batched kernel).
///
/// An env knob rather than a constructor flag because
/// [`crate::coordinator::channel::NativeCorruptor`] is a unit struct
/// built at dozens of call sites; the escape hatch must not require
/// threading configuration through all of them to be usable for
/// bisection.
pub fn kernel_mode() -> KernelMode {
    static MODE: OnceLock<KernelMode> = OnceLock::new();
    *MODE.get_or_init(|| match std::env::var("LORAX_KERNEL").as_deref() {
        Ok("scalar") => KernelMode::Scalar,
        _ => KernelMode::Batched,
    })
}

/// Local always-inline fmix32 copy for the vectorized loops.
#[inline(always)]
fn fmix32_inline(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x85EB_CA6B);
    x ^= x >> 13;
    x = x.wrapping_mul(0xC2B2_AE35);
    x ^= x >> 16;
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::float_bits::{corrupt_words_scalar, mask_for_lsbs};
    use crate::util::proptest::check;

    #[test]
    fn regimes_resolve_correctly() {
        assert_eq!(KernelDescriptor::new(0, ALWAYS, ALWAYS).regime, KernelRegime::Identity);
        assert_eq!(KernelDescriptor::new(0xFF, 0, 0).regime, KernelRegime::Identity);
        assert_eq!(KernelDescriptor::new(0xFF, ALWAYS, 0).regime, KernelRegime::Truncate);
        assert_eq!(KernelDescriptor::new(0xFF, ALWAYS, ALWAYS).regime, KernelRegime::Invert);
        assert_eq!(KernelDescriptor::new(0xFF, 7, 0).regime, KernelRegime::ReducedNoSet);
        assert_eq!(KernelDescriptor::new(0xFF, 7, 3).regime, KernelRegime::Stochastic);
        assert_eq!(KernelDescriptor::IDENTITY.regime, KernelRegime::Identity);
    }

    #[test]
    fn batched_matches_scalar_oracle_across_regimes() {
        check("kernel-batched-vs-scalar", 64, |g| {
            let n = g.usize(0, 1100); // crosses the 512-word chunk boundary
            let mask = if g.bool() { mask_for_lsbs(g.usize(0, 32) as u32) } else { g.u32() };
            let (t10, t01, seed) = (g.u32(), g.u32(), g.u32());
            let mut batched: Vec<u32> = g.vec(n, |g| g.u32());
            let mut scalar = batched.clone();
            let desc = KernelDescriptor::new(mask, t10, t01);
            corrupt_words_batched(&mut batched, &desc, seed);
            corrupt_words_scalar(&mut scalar, mask, t10, t01, seed);
            assert_eq!(batched, scalar, "mask={mask:#x} t10={t10:#x} t01={t01:#x}");
        });
    }

    #[test]
    fn lane_tail_and_tiny_transfers() {
        // Odd lengths exercise the u64-pair remainder in Truncate and
        // Invert; 0 and 1 are the degenerate transfers.
        for n in [0usize, 1, 2, 3, 5, 63, 64, 65] {
            for (t10, t01) in [(ALWAYS, 0u32), (ALWAYS, ALWAYS)] {
                let mut batched: Vec<u32> =
                    (0..n as u32).map(|i| i.wrapping_mul(0x9E37_79B9)).collect();
                let mut scalar = batched.clone();
                let desc = KernelDescriptor::new(0x00FF_FF00, t10, t01);
                corrupt_words_batched(&mut batched, &desc, 9);
                corrupt_words_scalar(&mut scalar, 0x00FF_FF00, t10, t01, 9);
                assert_eq!(batched, scalar, "n={n} t10={t10:#x} t01={t01:#x}");
            }
        }
    }

    #[test]
    fn quality_loss_formula() {
        assert_eq!(KernelDescriptor::IDENTITY.quality_loss, 0.0);
        assert_eq!(KernelDescriptor::new(0xFFFF, 0, 0).quality_loss, 0.0);
        // Truncation: t10 == ALWAYS, so exactly popcount/32.
        assert_eq!(KernelDescriptor::new(0xFFFF, ALWAYS, 0).quality_loss, 0.5);
        let d = KernelDescriptor::new(0xFFFF, ALWAYS / 2 + 1, 0);
        assert!(d.quality_loss > 0.25 && d.quality_loss < 0.2500001, "{}", d.quality_loss);
    }

    #[test]
    fn kernel_mode_defaults_to_batched() {
        // CI never sets LORAX_KERNEL for the test run; the scalar path
        // is exercised end-to-end by the workflow's escape-hatch smoke.
        if std::env::var("LORAX_KERNEL").is_err() {
            assert_eq!(kernel_mode(), KernelMode::Batched);
        }
    }
}
