//! Registry-free (std-only) runtime telemetry: counters, gauges,
//! log2-bucket histograms and span timers behind one process-global
//! [`Registry`] of named metrics.
//!
//! Design contract (see `docs/ARCHITECTURE.md` § Telemetry):
//!
//! * **O(1) hot path.** Recording is a handful of `Relaxed` atomic
//!   adds on a pre-registered handle — no locks, no allocation, no
//!   formatting.  Registration (name → handle) takes a mutex once per
//!   call site; the [`metric_counter!`]/[`metric_gauge!`]/
//!   [`metric_histogram!`] macros cache the handle in a `OnceLock`
//!   static so steady-state recording never touches the registry map.
//! * **Write-only.** Nothing in the simulation, the sweep fabric or
//!   the serve loop ever *reads* a metric to make a decision, so the
//!   no-op mode is pinned to have zero effect on outputs: `lorax run
//!   --json` and `lorax sweep --json` are byte-identical with
//!   telemetry enabled, disabled ([`set_enabled`], `LORAX_TELEMETRY=0`)
//!   or compiled out (`--features notelemetry`).
//! * **Mergeable snapshots.** [`Registry::snapshot`] captures every
//!   metric; [`Snapshot`] supports `diff` (worker deltas), `merge`
//!   (fleet totals) and a flat `(name, u64)` pairs codec so subprocess
//!   workers ship their registry deltas to the coordinator over the
//!   existing `FromWorker` protocol (`exec::transport`).
//!
//! Rendered surfaces: [`Snapshot::to_ndjson`] (the stable
//! `{"record":"telemetry_snapshot",...}` line behind `lorax run
//! --metrics` / `lorax sweep --metrics` and the `metrics` query on the
//! `lorax serve` socket) and [`crate::report::metrics_text`]
//! (Prometheus-style text exposition).

mod metrics;
mod registry;

pub use metrics::{Counter, Gauge, Histogram, Span, HIST_BUCKETS};
pub use registry::{HistogramSnapshot, Registry, Snapshot};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// The process-global registry every instrumented layer records into.
///
/// One per process by design: subprocess `lorax worker`s accumulate
/// into their own and ship deltas back to the coordinator, which
/// absorbs them here so fleet-wide totals come out of one snapshot.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Runtime kill switch (default on).  `LORAX_TELEMETRY=0` in the
/// environment pins it off for the whole process lifetime.
static ENABLED: AtomicBool = AtomicBool::new(true);

fn env_enabled() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    *ENV.get_or_init(|| std::env::var("LORAX_TELEMETRY").map(|v| v != "0").unwrap_or(true))
}

/// True when recording primitives are live.  Always false under the
/// `notelemetry` compile-out feature.
#[inline]
pub fn enabled() -> bool {
    #[cfg(feature = "notelemetry")]
    {
        false
    }
    #[cfg(not(feature = "notelemetry"))]
    {
        env_enabled() && ENABLED.load(Ordering::Relaxed)
    }
}

/// Turn recording on or off at runtime (used by the overhead bench and
/// the byte-identity tests).  Has no effect under `notelemetry` or when
/// `LORAX_TELEMETRY=0` pinned the process off.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// A cached `&'static Counter` handle for a fixed metric name: the
/// registry lookup runs once per call site, every later hit is one
/// `OnceLock` load.  Usable anywhere in the crate:
///
/// ```
/// lorax::metric_counter!("doc.example.events").inc();
/// ```
#[macro_export]
macro_rules! metric_counter {
    ($name:expr) => {{
        static CELL: std::sync::OnceLock<std::sync::Arc<$crate::telemetry::Counter>> =
            std::sync::OnceLock::new();
        &**CELL.get_or_init(|| $crate::telemetry::global().counter($name))
    }};
}

/// A cached `&'static Gauge` handle for a fixed metric name (see
/// [`metric_counter!`]).
#[macro_export]
macro_rules! metric_gauge {
    ($name:expr) => {{
        static CELL: std::sync::OnceLock<std::sync::Arc<$crate::telemetry::Gauge>> =
            std::sync::OnceLock::new();
        &**CELL.get_or_init(|| $crate::telemetry::global().gauge($name))
    }};
}

/// A cached `&'static Histogram` handle for a fixed metric name (see
/// [`metric_counter!`]).
#[macro_export]
macro_rules! metric_histogram {
    ($name:expr) => {{
        static CELL: std::sync::OnceLock<std::sync::Arc<$crate::telemetry::Histogram>> =
            std::sync::OnceLock::new();
        &**CELL.get_or_init(|| $crate::telemetry::global().histogram($name))
    }};
}

/// Serializes tests that toggle [`set_enabled`] or assert recorded
/// values against the rest of the in-process test suite (the kill
/// switch is process-global, so a concurrent toggle would make any
/// recording assertion flaky).
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(all(test, not(feature = "notelemetry")))]
mod tests {
    use super::*;

    #[test]
    fn global_registry_macros_and_kill_switch() {
        let _guard = test_lock();
        // The macro handle and a direct registry lookup alias the same
        // counter.
        let a = metric_counter!("telemetry.test.shared");
        let b = global().counter("telemetry.test.shared");
        a.add(3);
        b.add(4);
        assert_eq!(a.get(), b.get());
        assert_eq!(a.get(), 7);
        // The kill switch stops recording without touching stored
        // values, and re-enabling resumes exactly where it left off.
        let c = metric_counter!("telemetry.test.kill_switch");
        set_enabled(false);
        assert!(!enabled());
        c.inc();
        assert_eq!(c.get(), 0);
        set_enabled(true);
        c.inc();
        assert_eq!(c.get(), 1);
    }
}
