//! [`Registry`] — named metric handles — and [`Snapshot`], the
//! point-in-time capture with diff/merge semantics and the stable
//! `telemetry_snapshot` NDJSON rendering.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::bench::json_f64;

use super::metrics::{Counter, Gauge, Histogram, HIST_BUCKETS};

fn lock<'m, T>(m: &'m Mutex<T>) -> std::sync::MutexGuard<'m, T> {
    // A poisoned registry map only means another thread panicked
    // mid-registration; the map itself is always in a valid state.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A group of named metrics.
///
/// Keys are dotted lowercase paths, `layer.subject[_unit]` —
/// `serve.requests`, `transport.bytes_sent`, `replay.wall_us` — unique
/// across all three kinds (registering `x` as both a counter and a
/// gauge is a caller bug and panics in debug builds only via the
/// distinct maps; the snapshot would render both).  Registration
/// get-or-creates behind a mutex; the returned `Arc` handle is the
/// O(1) hot-path recording surface.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// An empty registry (the process-global one is
    /// [`super::global`]).
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or register the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = lock(&self.counters);
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Get or register the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = lock(&self.gauges);
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Get or register the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = lock(&self.histograms);
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Capture every registered metric.
    pub fn snapshot(&self) -> Snapshot {
        let counters =
            lock(&self.counters).iter().map(|(k, c)| (k.clone(), c.get())).collect();
        let gauges = lock(&self.gauges).iter().map(|(k, g)| (k.clone(), g.get())).collect();
        let histograms = lock(&self.histograms)
            .iter()
            .map(|(k, h)| {
                let buckets: Vec<(u8, u64)> = h
                    .buckets()
                    .iter()
                    .enumerate()
                    .filter(|(_, &n)| n > 0)
                    .map(|(i, &n)| (i as u8, n))
                    .collect();
                (k.clone(), HistogramSnapshot { count: h.count(), sum: h.sum(), buckets })
            })
            .collect();
        Snapshot { counters, gauges, histograms }
    }

    /// Merge a flat-pairs delta (see [`Snapshot::to_pairs`]) into this
    /// registry's live metrics — how the coordinator folds subprocess
    /// worker deltas into the fleet-wide totals.  Bypasses the kill
    /// switch: a worker's already-recorded delta must not be dropped
    /// by the coordinator's own enable state.  Unknown key prefixes
    /// are ignored (forward compatibility).
    pub fn absorb_pairs(&self, pairs: &[(String, u64)]) {
        let mut hists: BTreeMap<&str, (u64, u64, Vec<(u8, u64)>)> = BTreeMap::new();
        for (key, v) in pairs {
            if let Some(name) = key.strip_prefix("c:") {
                self.counter(name).absorb(*v);
            } else if let Some(rest) = key.strip_prefix("h:") {
                let Some((name, field)) = rest.rsplit_once(':') else { continue };
                let slot = hists.entry(name).or_default();
                match field {
                    "n" => slot.0 += v,
                    "s" => slot.1 += v,
                    b => {
                        if let Some(i) = b.strip_prefix('b').and_then(|s| s.parse::<u8>().ok())
                        {
                            slot.2.push((i, *v));
                        }
                    }
                }
            }
        }
        for (name, (count, sum, buckets)) in hists {
            self.histogram(name).absorb(count, sum, &buckets);
        }
    }
}

/// One histogram's captured state: total count, total sum and the
/// sparse nonzero log2 buckets as `(bucket index, count)` pairs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Samples recorded.
    pub count: u64,
    /// Sum of recorded samples.
    pub sum: u64,
    /// Nonzero buckets, ascending index (index = sample bit length).
    pub buckets: Vec<(u8, u64)>,
}

impl HistogramSnapshot {
    /// Approximate quantile `q` in `[0, 1]`: the inclusive upper bound
    /// of the first bucket whose cumulative count reaches
    /// `ceil(q * count)` (0 when empty).  Log2 buckets bound the
    /// overestimate at 2x.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for &(i, n) in &self.buckets {
            seen += n;
            if seen >= target {
                return Histogram::bucket_bound(i as usize);
            }
        }
        self.max()
    }

    /// Upper bound of the highest nonzero bucket (0 when empty).
    pub fn max(&self) -> u64 {
        self.buckets.last().map(|&(i, _)| Histogram::bucket_bound(i as usize)).unwrap_or(0)
    }

    fn saturating_sub(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let mut old: BTreeMap<u8, u64> = earlier.buckets.iter().copied().collect();
        let buckets: Vec<(u8, u64)> = self
            .buckets
            .iter()
            .map(|&(i, n)| (i, n.saturating_sub(old.remove(&i).unwrap_or(0))))
            .filter(|&(_, n)| n > 0)
            .collect();
        HistogramSnapshot {
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
            buckets,
        }
    }

    fn merge(&mut self, other: &HistogramSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        let mut map: BTreeMap<u8, u64> = self.buckets.iter().copied().collect();
        for &(i, n) in &other.buckets {
            *map.entry(i).or_insert(0) += n;
        }
        self.buckets = map.into_iter().filter(|&(_, n)| n > 0).collect();
    }
}

/// A point-in-time capture of a [`Registry`].
///
/// Counters and histograms are cumulative, so `later.diff(&earlier)`
/// is the activity in between (the worker-delta primitive) and
/// `merge` adds two captures (the fleet-total primitive).  Gauges are
/// levels: diff keeps the later level, merge sums.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge levels by name.
    pub gauges: BTreeMap<String, i64>,
    /// Histogram captures by name.
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl Snapshot {
    /// True when nothing was ever registered or every tally is zero.
    pub fn is_empty(&self) -> bool {
        self.counters.values().all(|&v| v == 0)
            && self.gauges.values().all(|&v| v == 0)
            && self.histograms.values().all(|h| h.count == 0)
    }

    /// Counter value by name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// What happened between `earlier` and `self` (saturating per
    /// key; keys only in `self` pass through whole).
    pub fn diff(&self, earlier: &Snapshot) -> Snapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, &v)| (k.clone(), v.saturating_sub(earlier.counter(k))))
            .filter(|&(_, v)| v > 0)
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(k, h)| {
                let d = match earlier.histograms.get(k) {
                    Some(e) => h.saturating_sub(e),
                    None => h.clone(),
                };
                (k.clone(), d)
            })
            .filter(|(_, h)| h.count > 0)
            .collect();
        Snapshot { counters, gauges: self.gauges.clone(), histograms }
    }

    /// Add `other`'s tallies into `self`.
    pub fn merge(&mut self, other: &Snapshot) {
        for (k, &v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, &v) in &other.gauges {
            *self.gauges.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Flatten to `(key, u64)` pairs for the wire: counters as
    /// `c:<name>`, histograms as `h:<name>:n` / `h:<name>:s` /
    /// `h:<name>:b<i>`.  Gauges are point-in-time levels and do not
    /// travel.  Inverse of [`Snapshot::from_pairs`].
    pub fn to_pairs(&self) -> Vec<(String, u64)> {
        let mut out = Vec::new();
        for (k, &v) in &self.counters {
            if v > 0 {
                out.push((format!("c:{k}"), v));
            }
        }
        for (k, h) in &self.histograms {
            if h.count == 0 {
                continue;
            }
            out.push((format!("h:{k}:n"), h.count));
            out.push((format!("h:{k}:s"), h.sum));
            for &(i, n) in &h.buckets {
                out.push((format!("h:{k}:b{i}"), n));
            }
        }
        out
    }

    /// Rebuild a snapshot from [`Snapshot::to_pairs`] output.
    /// Unknown key prefixes are ignored.
    pub fn from_pairs(pairs: &[(String, u64)]) -> Snapshot {
        let reg = Registry::new();
        reg.absorb_pairs(pairs);
        reg.snapshot()
    }

    /// The stable machine-readable rendering: one newline-terminated
    /// `{"record":"telemetry_snapshot",...}` object with flat sorted
    /// keys — counters and gauges by name, histograms as
    /// `<name>.count` / `<name>.sum` / `<name>.p50` / `<name>.p95` /
    /// `<name>.max` (quantiles are log2-bucket upper bounds; schema in
    /// docs/BENCHMARKS.md).  Printed by `lorax run --metrics`,
    /// `lorax sweep --metrics` and the `metrics` serve query.
    pub fn to_ndjson(&self) -> String {
        let mut out = String::from("{\"record\":\"telemetry_snapshot\"");
        for (k, v) in &self.counters {
            out.push_str(&format!(",{k:?}:{v}"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!(",{k:?}:{v}"));
        }
        for (k, h) in &self.histograms {
            out.push_str(&format!(
                ",\"{k}.count\":{},\"{k}.sum\":{},\"{k}.p50\":{},\"{k}.p95\":{},\
                 \"{k}.max\":{}",
                h.count,
                h.sum,
                h.quantile(0.5),
                h.quantile(0.95),
                h.max(),
            ));
        }
        out.push_str("}\n");
        out
    }

    /// Human-oriented multi-line rendering (used by the non-JSON
    /// `--metrics` output; one aligned `name value` row per metric).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("  {k:<36} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("  {k:<36} {v}\n"));
        }
        for (k, h) in &self.histograms {
            let mean = if h.count > 0 { h.sum as f64 / h.count as f64 } else { 0.0 };
            out.push_str(&format!(
                "  {k:<36} n={} mean={} p95<={} max<={}\n",
                h.count,
                json_f64((mean * 10.0).round() / 10.0),
                h.quantile(0.95),
                h.max(),
            ));
        }
        out
    }
}

#[cfg(all(test, not(feature = "notelemetry")))]
mod tests {
    use super::*;

    fn sample() -> Registry {
        let reg = Registry::new();
        reg.counter("a.hits").add(5);
        reg.counter("a.misses").add(2);
        reg.gauge("b.level").set(-3);
        let h = reg.histogram("c.lat_us");
        for v in [1u64, 3, 3, 900, 70_000] {
            h.record(v);
        }
        reg
    }

    #[test]
    fn snapshot_captures_everything() {
        let _guard = crate::telemetry::test_lock();
        let s = sample().snapshot();
        assert_eq!(s.counter("a.hits"), 5);
        assert_eq!(s.counter("a.misses"), 2);
        assert_eq!(s.counter("nope"), 0);
        assert_eq!(s.gauges["b.level"], -3);
        let h = &s.histograms["c.lat_us"];
        assert_eq!(h.count, 5);
        assert_eq!(h.sum, 70_907);
        assert!(!s.is_empty());
        assert!(Registry::new().snapshot().is_empty());
    }

    #[test]
    fn diff_and_merge_are_inverse_ish() {
        let _guard = crate::telemetry::test_lock();
        let reg = sample();
        let before = reg.snapshot();
        reg.counter("a.hits").add(10);
        reg.counter("d.new").add(1);
        reg.histogram("c.lat_us").record(900);
        let after = reg.snapshot();
        let delta = after.diff(&before);
        assert_eq!(delta.counter("a.hits"), 10);
        assert_eq!(delta.counter("d.new"), 1);
        assert_eq!(delta.counter("a.misses"), 0); // unchanged keys drop out
        assert_eq!(delta.histograms["c.lat_us"].count, 1);
        assert_eq!(delta.histograms["c.lat_us"].sum, 900);
        let mut rebuilt = before.clone();
        rebuilt.merge(&delta);
        assert_eq!(rebuilt.counter("a.hits"), after.counter("a.hits"));
        assert_eq!(rebuilt.histograms["c.lat_us"], after.histograms["c.lat_us"]);
    }

    #[test]
    fn pairs_round_trip_and_absorb() {
        let _guard = crate::telemetry::test_lock();
        let s = sample().snapshot();
        let pairs = s.to_pairs();
        let back = Snapshot::from_pairs(&pairs);
        assert_eq!(back.counters, s.counters);
        assert_eq!(back.histograms, s.histograms);
        assert!(back.gauges.is_empty(), "gauges must not travel");
        // Absorbing the same delta twice doubles the tallies.
        let reg = Registry::new();
        reg.absorb_pairs(&pairs);
        reg.absorb_pairs(&pairs);
        let twice = reg.snapshot();
        assert_eq!(twice.counter("a.hits"), 10);
        assert_eq!(twice.histograms["c.lat_us"].count, 10);
        // Unknown prefixes are ignored.
        reg.absorb_pairs(&[("x:weird".to_string(), 7), ("h:broken".to_string(), 7)]);
    }

    #[test]
    fn quantiles_are_bucket_upper_bounds() {
        let s = sample().snapshot();
        let h = &s.histograms["c.lat_us"];
        // Samples 1, 3, 3, 900, 70000 -> p50 is in the bit-length-2
        // bucket (bound 3); max is in the 70k bucket (bound 131071).
        assert_eq!(h.quantile(0.5), 3);
        assert_eq!(h.max(), 131_071);
        assert!(h.quantile(0.95) >= 900);
        assert_eq!(HistogramSnapshot::default().quantile(0.5), 0);
    }

    #[test]
    fn ndjson_is_one_flat_sorted_record() {
        let _guard = crate::telemetry::test_lock();
        let line = sample().snapshot().to_ndjson();
        assert!(line.starts_with("{\"record\":\"telemetry_snapshot\""));
        assert!(line.ends_with("}\n"));
        assert_eq!(line.matches('\n').count(), 1);
        assert!(line.contains("\"a.hits\":5"));
        assert!(line.contains("\"b.level\":-3"));
        assert!(line.contains("\"c.lat_us.count\":5"));
        assert!(line.contains("\"c.lat_us.sum\":70907"));
        let text = sample().snapshot().to_text();
        assert!(text.contains("a.hits"));
        assert!(text.contains("n=5"));
    }
}
