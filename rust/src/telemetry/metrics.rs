//! The atomic metric primitives: [`Counter`], [`Gauge`], [`Histogram`]
//! and the [`Span`] timer.
//!
//! All recording goes through `Ordering::Relaxed` atomics — metrics
//! are monotone tallies, not synchronization — and every recording
//! entry point early-returns when [`super::enabled`] is false, so the
//! no-op mode costs one relaxed load.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::time::Instant;

/// Number of log2 buckets a [`Histogram`] carries: bucket `i` counts
/// values whose bit length is `i` (bucket 0 counts zero), i.e. value
/// `v > 0` lands in bucket `64 - v.leading_zeros()`, capped at
/// `HIST_BUCKETS - 1`.
pub const HIST_BUCKETS: usize = 64;

/// A monotonically increasing event tally.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// A counter at zero.
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    /// Count one event.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Count `n` events.
    #[inline]
    pub fn add(&self, n: u64) {
        if super::enabled() {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// Merge a snapshot delta in, bypassing the kill switch: fleet
    /// aggregation must not drop worker deltas just because the
    /// coordinator's own recording is off.
    pub(crate) fn absorb(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
}

/// A signed point-in-time level (e.g. requests currently in flight).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    /// A gauge at zero.
    pub const fn new() -> Gauge {
        Gauge { v: AtomicI64::new(0) }
    }

    /// Set the level.
    #[inline]
    pub fn set(&self, v: i64) {
        if super::enabled() {
            self.v.store(v, Ordering::Relaxed);
        }
    }

    /// Move the level up by `n`.
    #[inline]
    pub fn add(&self, n: i64) {
        if super::enabled() {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Move the level down by `n`.
    #[inline]
    pub fn sub(&self, n: i64) {
        self.add(-n);
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A fixed-size log2-bucket histogram of `u64` samples (latencies in
/// microseconds by convention — name metrics `*_us`).
///
/// Fixed buckets keep recording allocation-free and snapshots
/// mergeable bucket-by-bucket; log2 spacing covers nanoseconds to
/// hours in [`HIST_BUCKETS`] slots at ≤ 2x quantile resolution.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Bucket index for a sample (its bit length, capped).
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        ((u64::BITS - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` (0 for the zero bucket).
    pub fn bucket_bound(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 63 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    /// Record one sample.
    #[inline]
    pub fn record(&self, v: u64) {
        if super::enabled() {
            self.count.fetch_add(1, Ordering::Relaxed);
            self.sum.fetch_add(v, Ordering::Relaxed);
            self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded samples.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Raw bucket counts (index = bit length of the sample).
    pub fn buckets(&self) -> [u64; HIST_BUCKETS] {
        let mut out = [0u64; HIST_BUCKETS];
        for (o, b) in out.iter_mut().zip(self.buckets.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Start a wall-clock [`Span`] that records elapsed microseconds
    /// into this histogram when dropped.
    pub fn span(&self) -> Span<'_> {
        Span { hist: self, start: Instant::now() }
    }

    /// Merge snapshot data in (fleet aggregation; bypasses the kill
    /// switch like [`Counter::absorb`]).
    pub(crate) fn absorb(&self, count: u64, sum: u64, buckets: &[(u8, u64)]) {
        self.count.fetch_add(count, Ordering::Relaxed);
        self.sum.fetch_add(sum, Ordering::Relaxed);
        for &(i, n) in buckets {
            let i = (i as usize).min(HIST_BUCKETS - 1);
            self.buckets[i].fetch_add(n, Ordering::Relaxed);
        }
    }
}

/// A lightweight wall-clock timer: created by [`Histogram::span`],
/// records elapsed **microseconds** into its histogram on drop.
///
/// ```
/// let hist = lorax::telemetry::global().histogram("doc.example.phase_us");
/// {
///     let _span = hist.span();
///     // ... timed phase ...
/// } // drop records the elapsed time
/// ```
#[must_use = "a Span records on drop; binding it to _ drops it immediately"]
#[derive(Debug)]
pub struct Span<'h> {
    hist: &'h Histogram,
    start: Instant,
}

impl Span<'_> {
    /// Microseconds elapsed so far.
    pub fn elapsed_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        self.hist.record(self.elapsed_us());
    }
}

#[cfg(all(test, not(feature = "notelemetry")))]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let _guard = crate::telemetry::test_lock();
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.add(3);
        g.sub(5);
        assert_eq!(g.get(), -2);
        g.set(9);
        assert_eq!(g.get(), 9);
    }

    #[test]
    fn histogram_buckets_are_log2_by_bit_length() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(1023), 10);
        assert_eq!(Histogram::bucket_index(1024), 11);
        assert_eq!(Histogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // Bounds are the inclusive top of each bucket.
        assert_eq!(Histogram::bucket_bound(0), 0);
        assert_eq!(Histogram::bucket_bound(1), 1);
        assert_eq!(Histogram::bucket_bound(10), 1023);
        assert_eq!(Histogram::bucket_bound(63), u64::MAX);
        for v in [0u64, 1, 2, 3, 7, 8, 1000, 123_456_789] {
            let i = Histogram::bucket_index(v);
            assert!(v <= Histogram::bucket_bound(i), "{v} above bound of bucket {i}");
            if i > 0 {
                assert!(v > Histogram::bucket_bound(i - 1), "{v} fits bucket {}", i - 1);
            }
        }
    }

    #[test]
    fn histogram_records_and_spans() {
        let _guard = crate::telemetry::test_lock();
        let h = Histogram::new();
        for v in [0u64, 1, 5, 5, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1011);
        let b = h.buckets();
        assert_eq!(b[0], 1);
        assert_eq!(b[1], 1);
        assert_eq!(b[3], 2);
        assert_eq!(b[10], 1);
        {
            let _span = h.span();
        }
        assert_eq!(h.count(), 6);
    }
}
